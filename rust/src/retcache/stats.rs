//! Hit-rate and saved-latency accounting for the retrieval cache +
//! speculation path, exportable into [`crate::util::metrics::Metrics`]
//! and renderable into the serve reports.

use crate::util::json::{obj, Json};
use crate::util::metrics::Metrics;

use super::cache::RetrievalCache;
use super::spec::SpecSlots;

/// Where a retrieval was served from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetrievalSource {
    /// Full coordinator -> ChamVS round trip.
    Miss,
    /// Served from the retrieval cache.
    CacheHit,
    /// Served from a verified speculative prefetch.
    SpecHit,
}

/// Per-retriever counters over the cached serving path.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RetrievalStats {
    pub misses: u64,
    pub cache_hits: u64,
    pub spec_hits: u64,
    /// Modeled seconds the cached/speculative path saved vs the
    /// synchronous baseline (sum of full latency minus charged latency).
    pub saved_modeled_s: f64,
}

impl RetrievalStats {
    /// Count a retrieval by source only (the saved-latency term is added
    /// by the serving layer, which knows the decode overlap window).
    pub fn count(&mut self, source: RetrievalSource) {
        self.record(source, 0.0, 0.0);
    }

    pub fn record(&mut self, source: RetrievalSource, full_s: f64, charged_s: f64) {
        match source {
            RetrievalSource::Miss => self.misses += 1,
            RetrievalSource::CacheHit => self.cache_hits += 1,
            RetrievalSource::SpecHit => self.spec_hits += 1,
        }
        self.saved_modeled_s += (full_s - charged_s).max(0.0);
    }

    pub fn total(&self) -> u64 {
        self.misses + self.cache_hits + self.spec_hits
    }

    /// Fraction of retrievals that avoided the full round trip.
    pub fn served_fast_rate(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            (self.cache_hits + self.spec_hits) as f64 / t as f64
        }
    }

    /// Counter-wise difference (for snapshot/delta accounting around a
    /// serving run).
    pub fn delta_since(&self, earlier: &RetrievalStats) -> RetrievalStats {
        RetrievalStats {
            misses: self.misses - earlier.misses,
            cache_hits: self.cache_hits - earlier.cache_hits,
            spec_hits: self.spec_hits - earlier.spec_hits,
            saved_modeled_s: self.saved_modeled_s - earlier.saved_modeled_s,
        }
    }

    /// Push the counters into a metrics registry under `retcache.*`.
    ///
    /// Lifetime totals go through `incr` — export once per registry (or
    /// export deltas via [`delta_since`](Self::delta_since)); repeated
    /// full exports would double-count. Point-in-time gauges (cache
    /// bytes/entries) go through `observe`, which is repeat-safe.
    pub fn export(
        &self,
        m: &Metrics,
        cache: Option<&RetrievalCache>,
        spec: Option<&SpecSlots>,
    ) {
        m.incr("retcache.misses", self.misses);
        m.incr("retcache.cache_hits", self.cache_hits);
        m.incr("retcache.spec_hits", self.spec_hits);
        m.observe("retcache.saved_modeled_s", self.saved_modeled_s);
        if let Some(c) = cache {
            m.observe("retcache.cache_bytes", c.bytes() as f64);
            m.observe("retcache.cache_entries", c.len() as f64);
            m.incr("retcache.cache_evictions", c.evictions);
        }
        if let Some(s) = spec {
            m.incr("retcache.spec_issued", s.issued());
            m.incr("retcache.spec_verified", s.verified());
            m.incr("retcache.spec_rejected", s.rejected());
            m.observe("retcache.spec_slots", s.n_slots() as f64);
        }
    }

    /// Mirror the counters into a telemetry [`Registry`] as *absolute*
    /// gauges under `retcache.*`. Unlike [`export`](Self::export) this is
    /// repeat-safe: the serving loop calls it after every batch so
    /// mid-run scrapes see live hit rates, and re-exporting just
    /// overwrites with the current value.
    pub fn export_telemetry(
        &self,
        reg: &crate::telemetry::Registry,
        cache: Option<&RetrievalCache>,
        spec: Option<&SpecSlots>,
    ) {
        reg.gauge("retcache.misses").set(self.misses);
        reg.gauge("retcache.cache_hits").set(self.cache_hits);
        reg.gauge("retcache.spec_hits").set(self.spec_hits);
        reg.gauge("retcache.saved_modeled_ms")
            .set((self.saved_modeled_s * 1e3) as u64);
        if let Some(c) = cache {
            reg.gauge("retcache.cache_bytes").set(c.bytes() as u64);
            reg.gauge("retcache.cache_entries").set(c.len() as u64);
            reg.gauge("retcache.cache_evictions").set(c.evictions);
        }
        if let Some(s) = spec {
            reg.gauge("retcache.spec_issued").set(s.issued());
            reg.gauge("retcache.spec_verified").set(s.verified());
            reg.gauge("retcache.spec_rejected").set(s.rejected());
            reg.gauge("retcache.spec_slots").set(s.n_slots() as u64);
        }
    }

    /// JSON export for report plumbing.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("misses", Json::Num(self.misses as f64)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("spec_hits", Json::Num(self.spec_hits as f64)),
            ("saved_modeled_s", Json::Num(self.saved_modeled_s)),
        ])
    }

    /// Human-readable block for the serve reports.
    pub fn render(&self, cache: Option<&RetrievalCache>, spec: Option<&SpecSlots>) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "retcache: {} retrievals | miss {} | cache-hit {} | spec-hit {} | fast-served {:.1}%\n",
            self.total(),
            self.misses,
            self.cache_hits,
            self.spec_hits,
            self.served_fast_rate() * 100.0,
        ));
        out.push_str(&format!(
            "retcache: saved {:.3} ms modeled retrieval latency\n",
            self.saved_modeled_s * 1e3
        ));
        if let Some(c) = cache {
            out.push_str(&format!(
                "retcache: cache {} entries / {} B used of {} B | lifetime hit-rate {:.1}% | {} evictions ({:?})\n",
                c.len(),
                c.bytes(),
                c.cfg.capacity_bytes,
                c.hit_rate() * 100.0,
                c.evictions,
                c.cfg.policy,
            ));
        }
        if let Some(s) = spec {
            out.push_str(&format!(
                "retcache: speculation issued {} | verified {} | rejected {} | accuracy {:.1}% | {} slot(s)\n",
                s.issued(),
                s.verified(),
                s.rejected(),
                s.accuracy() * 100.0,
                s.n_slots().max(1),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retcache::cache::{CacheConfig, RetrievalCache};
    use crate::retcache::spec::{SpecConfig, SpecSlots};

    #[test]
    fn record_accumulates_sources_and_savings() {
        let mut s = RetrievalStats::default();
        s.record(RetrievalSource::Miss, 1e-3, 1e-3);
        s.record(RetrievalSource::CacheHit, 1e-3, 2e-6);
        s.record(RetrievalSource::SpecHit, 1e-3, 4e-4);
        assert_eq!(s.total(), 3);
        assert!((s.served_fast_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.saved_modeled_s - (998e-6 + 6e-4)).abs() < 1e-9);
    }

    #[test]
    fn delta_since_subtracts() {
        let mut s = RetrievalStats::default();
        s.record(RetrievalSource::Miss, 1e-3, 1e-3);
        let snap = s;
        s.record(RetrievalSource::CacheHit, 1e-3, 0.0);
        let d = s.delta_since(&snap);
        assert_eq!(d.misses, 0);
        assert_eq!(d.cache_hits, 1);
    }

    #[test]
    fn export_populates_metrics() {
        let mut s = RetrievalStats::default();
        s.record(RetrievalSource::CacheHit, 1e-3, 0.0);
        let cache = RetrievalCache::new(CacheConfig::default());
        let spec = SpecSlots::new(SpecConfig::default());
        let m = Metrics::new();
        s.export(&m, Some(&cache), Some(&spec));
        assert_eq!(m.counter("retcache.cache_hits"), 1);
        assert_eq!(m.counter("retcache.spec_issued"), 0);
        let j = m.to_json().dump();
        assert!(j.contains("retcache.cache_hits"), "{j}");
    }

    #[test]
    fn render_mentions_all_counter_groups() {
        let mut s = RetrievalStats::default();
        s.record(RetrievalSource::SpecHit, 1e-3, 1e-4);
        let cache = RetrievalCache::new(CacheConfig::default());
        let spec = SpecSlots::new(SpecConfig::default());
        let out = s.render(Some(&cache), Some(&spec));
        assert!(out.contains("cache-hit"));
        assert!(out.contains("spec-hit"));
        assert!(out.contains("speculation issued"));
        assert!(out.contains("saved"));
    }
}
