//! Cache keys over query embeddings.
//!
//! RALM retrieval queries are hidden-state projections: byte-identical
//! repeats happen (same prompt, replayed request), but near-identical
//! queries whose retrieval results agree are far more common (RaLMSpec's
//! observation). The cache therefore supports two keying modes:
//!
//! * **Exact** — the raw f32 bit pattern; hits only on byte-identical
//!   queries (no recall risk).
//! * **Quantized** — each component snapped to a fixed grid, so queries
//!   within ~`grid/2` per dimension collapse to one key. Coarser grids
//!   trade retrieval fidelity for hit rate, exactly like the PQ trade-off
//!   the paper's accelerator is built around.

use crate::util::rng::Rng;

/// How queries are mapped to cache keys.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyPolicy {
    /// Bit-exact f32 key.
    Exact,
    /// Components snapped to a grid of this step size (must be > 0).
    Quantized(f32),
}

impl KeyPolicy {
    /// Build the key for a query under this policy.
    pub fn key(&self, query: &[f32]) -> CacheKey {
        match *self {
            KeyPolicy::Exact => CacheKey::Exact(query.iter().map(|x| x.to_bits()).collect()),
            KeyPolicy::Quantized(grid) => {
                assert!(grid > 0.0, "quantization grid must be positive");
                CacheKey::Quantized(
                    query
                        .iter()
                        .map(|&x| {
                            let q = (x / grid).round();
                            q.clamp(i16::MIN as f32, i16::MAX as f32) as i16
                        })
                        .collect(),
                )
            }
        }
    }
}

/// A hashed cache key (exact bits or quantized grid coordinates).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum CacheKey {
    Exact(Vec<u32>),
    Quantized(Vec<i16>),
}

impl CacheKey {
    /// Bytes this key occupies in the cache (budget accounting).
    pub fn bytes(&self) -> usize {
        match self {
            CacheKey::Exact(v) => 4 * v.len(),
            CacheKey::Quantized(v) => 2 * v.len(),
        }
    }
}

/// Deterministic jitter helper for tests: `query + uniform(-eps, eps)`.
pub fn jitter(query: &[f32], eps: f32, rng: &mut Rng) -> Vec<f32> {
    query.iter().map(|&x| x + (rng.f32() * 2.0 - 1.0) * eps).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_key_distinguishes_bit_changes() {
        let a = KeyPolicy::Exact.key(&[1.0, 2.0]);
        let b = KeyPolicy::Exact.key(&[1.0, 2.0]);
        let c = KeyPolicy::Exact.key(&[1.0, 2.0 + 1e-7]);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn quantized_key_collapses_nearby_queries() {
        let p = KeyPolicy::Quantized(0.1);
        let base = vec![0.5f32, -1.2, 3.3];
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let near = jitter(&base, 0.01, &mut rng);
            assert_eq!(p.key(&base), p.key(&near));
        }
        // A full grid step away must differ.
        let far: Vec<f32> = base.iter().map(|x| x + 0.2).collect();
        assert_ne!(p.key(&base), p.key(&far));
    }

    #[test]
    fn key_bytes_scale_with_dim() {
        assert_eq!(KeyPolicy::Exact.key(&[0.0; 128]).bytes(), 512);
        assert_eq!(KeyPolicy::Quantized(0.5).key(&[0.0; 128]).bytes(), 256);
    }

    #[test]
    fn quantized_clamps_extremes() {
        let p = KeyPolicy::Quantized(1e-6);
        // Would overflow i16 without clamping; must not panic.
        let k = p.key(&[1e9, -1e9]);
        assert_eq!(k, CacheKey::Quantized(vec![i16::MAX, i16::MIN]));
    }
}
