//! Speculative retrieval (RaLMSpec-style): while the GPU decodes the next
//! `interval` tokens, the coordinator already has a *predicted* next query
//! in flight on ChamVS. When the real query materializes it is verified
//! against the prediction; on a match the prefetched result is consumed
//! and only the retrieval latency not hidden behind decode is charged, on
//! a mismatch the in-flight query is cancelled and a normal retrieval
//! runs.
//!
//! Exactness: with `tolerance = 0` a verified speculation is bit-exact
//! (the prefetched scan ran the identical query) and speculation changes
//! latency, never results. A *nonzero* tolerance is an approximation
//! knob, like quantized cache keys: a verified hit serves the *predicted*
//! query's neighbors, which near PQ distance boundaries can differ from
//! the drifted real query's — the documented fidelity/latency trade-off.
//!
//! The predictor is query-continuity: consecutive retrieval queries come
//! from consecutive hidden states of the same sequence, so "next query ==
//! current query (within tolerance)" is the highest-value single guess —
//! the same locality RaLMSpec exploits with its caching speculator.

use std::collections::BTreeMap;

use crate::chamvs::dispatcher::Ticket;

/// Speculation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SpecConfig {
    /// Mean per-dimension squared distance below which the real query is
    /// considered to match the prediction. 0 = bit-exact only.
    pub tolerance: f32,
    /// How many retrieval intervals ahead the prefetch is issued (the
    /// overlap window is `depth * interval` decode steps). The in-process
    /// speculator keeps one prediction in flight; depth scales how much
    /// decode time the serving layer may credit against it.
    pub depth: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { tolerance: 1e-4, depth: 1 }
    }
}

/// Outcome of verifying the real query against the in-flight prediction.
#[derive(Debug, PartialEq, Eq)]
pub enum SpecVerdict {
    /// Prediction matched; consume this in-flight ticket.
    Hit(Ticket),
    /// Prediction missed; cancel this ticket and retrieve normally.
    Reject(Ticket),
    /// Nothing was in flight.
    Idle,
}

/// Tracks the single in-flight speculative query and its accuracy.
pub struct Speculator {
    pub cfg: SpecConfig,
    in_flight: Option<(Ticket, Vec<f32>)>,
    pub issued: u64,
    pub verified: u64,
    pub rejected: u64,
}

impl Speculator {
    pub fn new(cfg: SpecConfig) -> Speculator {
        Speculator { cfg, in_flight: None, issued: 0, verified: 0, rejected: 0 }
    }

    /// The next-query prediction given the query that just retrieved.
    pub fn predict(&self, current: &[f32]) -> Vec<f32> {
        current.to_vec()
    }

    /// Record a newly submitted prefetch.
    pub fn set_in_flight(&mut self, ticket: Ticket, predicted: Vec<f32>) {
        self.in_flight = Some((ticket, predicted));
        self.issued += 1;
    }

    /// Take the outstanding ticket without verification (cancellation on
    /// sequence boundaries / cache reconfiguration).
    pub fn take_in_flight(&mut self) -> Option<Ticket> {
        self.in_flight.take().map(|(t, _)| t)
    }

    pub fn has_in_flight(&self) -> bool {
        self.in_flight.is_some()
    }

    /// Whether the in-flight prediction is exactly this query (used to
    /// keep predictions fresh across cache hits without re-submitting).
    pub fn predicts(&self, query: &[f32]) -> bool {
        self.in_flight.as_ref().is_some_and(|(_, p)| p.as_slice() == query)
    }

    /// Verify the real query against the in-flight prediction, consuming
    /// it either way (hit -> poll the ticket, reject -> cancel it).
    pub fn verify_take(&mut self, query: &[f32]) -> SpecVerdict {
        match self.in_flight.take() {
            None => SpecVerdict::Idle,
            Some((ticket, predicted)) => {
                if Self::close(query, &predicted, self.cfg.tolerance) {
                    self.verified += 1;
                    SpecVerdict::Hit(ticket)
                } else {
                    self.rejected += 1;
                    SpecVerdict::Reject(ticket)
                }
            }
        }
    }

    /// Fraction of issued speculations that verified (0 when none issued).
    pub fn accuracy(&self) -> f64 {
        let settled = self.verified + self.rejected;
        if settled == 0 {
            0.0
        } else {
            self.verified as f64 / settled as f64
        }
    }

    fn close(a: &[f32], b: &[f32], tolerance: f32) -> bool {
        if a.len() != b.len() || a.is_empty() {
            return false;
        }
        let msd: f32 = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            / a.len() as f32;
        msd <= tolerance
    }
}

/// Per-GPU speculation lanes: each GPU source ("slot") owns an
/// independent [`Speculator`], so verify/cancel on one decode stream
/// never disturbs another stream's in-flight prefetch — the RaLMSpec
/// isolation property the single global pending list could not provide.
/// Slots are created lazily on first use and share one [`SpecConfig`].
pub struct SpecSlots {
    pub cfg: SpecConfig,
    slots: BTreeMap<usize, Speculator>,
}

impl SpecSlots {
    pub fn new(cfg: SpecConfig) -> SpecSlots {
        SpecSlots { cfg, slots: BTreeMap::new() }
    }

    /// The lane for one GPU source, created on first touch.
    pub fn slot_mut(&mut self, slot: usize) -> &mut Speculator {
        let cfg = self.cfg;
        self.slots.entry(slot).or_insert_with(|| Speculator::new(cfg))
    }

    /// Read-only view of a lane (None if the slot never speculated).
    pub fn slot(&self, slot: usize) -> Option<&Speculator> {
        self.slots.get(&slot)
    }

    /// Number of lanes that have ever been touched.
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// (slot id, lane) pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (&usize, &Speculator)> {
        self.slots.iter()
    }

    /// Speculations issued across all lanes.
    pub fn issued(&self) -> u64 {
        self.slots.values().map(|s| s.issued).sum()
    }

    /// Speculations verified across all lanes.
    pub fn verified(&self) -> u64 {
        self.slots.values().map(|s| s.verified).sum()
    }

    /// Speculations rejected across all lanes.
    pub fn rejected(&self) -> u64 {
        self.slots.values().map(|s| s.rejected).sum()
    }

    /// Aggregate accuracy over all settled speculations (0 when none).
    pub fn accuracy(&self) -> f64 {
        let settled = self.verified() + self.rejected();
        if settled == 0 {
            0.0
        } else {
            self.verified() as f64 / settled as f64
        }
    }

    /// Whether `slot`'s in-flight prediction is exactly this query.
    pub fn predicts(&self, slot: usize, query: &[f32]) -> bool {
        self.slot(slot).is_some_and(|s| s.predicts(query))
    }

    pub fn has_in_flight(&self, slot: usize) -> bool {
        self.slot(slot).is_some_and(|s| s.has_in_flight())
    }

    /// In-flight prefetches across all lanes.
    pub fn in_flight_total(&self) -> usize {
        self.slots.values().filter(|s| s.has_in_flight()).count()
    }

    /// Take one lane's outstanding ticket without verification.
    pub fn take_in_flight(&mut self, slot: usize) -> Option<Ticket> {
        self.slots.get_mut(&slot).and_then(|s| s.take_in_flight())
    }

    /// Take every lane's outstanding ticket (teardown — the caller
    /// cancels them on the dispatcher). Not counted as settled.
    pub fn take_all_in_flight(&mut self) -> Vec<Ticket> {
        self.slots.values_mut().filter_map(|s| s.take_in_flight()).collect()
    }

    /// Verify the real query against one lane's in-flight prediction.
    pub fn verify_take(&mut self, slot: usize, query: &[f32]) -> SpecVerdict {
        match self.slots.get_mut(&slot) {
            Some(s) => s.verify_take(query),
            None => SpecVerdict::Idle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_without_prefetch() {
        let mut s = Speculator::new(SpecConfig::default());
        assert_eq!(s.verify_take(&[1.0, 2.0]), SpecVerdict::Idle);
        assert_eq!(s.accuracy(), 0.0);
    }

    #[test]
    fn exact_match_verifies() {
        let mut s = Speculator::new(SpecConfig { tolerance: 0.0, depth: 1 });
        let q = vec![0.5f32; 16];
        s.set_in_flight(Ticket(7), s.predict(&q));
        assert!(s.has_in_flight());
        assert_eq!(s.verify_take(&q), SpecVerdict::Hit(Ticket(7)));
        assert!(!s.has_in_flight());
        assert_eq!(s.verified, 1);
        assert_eq!(s.accuracy(), 1.0);
    }

    #[test]
    fn far_query_rejects_and_consumes() {
        let mut s = Speculator::new(SpecConfig { tolerance: 1e-4, depth: 1 });
        s.set_in_flight(Ticket(3), vec![0.0f32; 16]);
        let far = vec![1.0f32; 16];
        assert_eq!(s.verify_take(&far), SpecVerdict::Reject(Ticket(3)));
        assert_eq!(s.rejected, 1);
        assert_eq!(s.verify_take(&far), SpecVerdict::Idle, "consumed either way");
        assert_eq!(s.accuracy(), 0.0);
    }

    #[test]
    fn tolerance_admits_drifted_queries() {
        let mut s = Speculator::new(SpecConfig { tolerance: 1e-2, depth: 1 });
        let q = vec![0.5f32; 16];
        let drifted: Vec<f32> = q.iter().map(|x| x + 0.05).collect();
        s.set_in_flight(Ticket(1), q.clone());
        assert_eq!(s.verify_take(&drifted), SpecVerdict::Hit(Ticket(1)));
        // Dimension mismatch never verifies.
        s.set_in_flight(Ticket(2), q);
        assert_eq!(s.verify_take(&[0.5f32; 8]), SpecVerdict::Reject(Ticket(2)));
    }

    #[test]
    fn take_in_flight_cancels_silently() {
        let mut s = Speculator::new(SpecConfig::default());
        s.set_in_flight(Ticket(9), vec![1.0]);
        assert_eq!(s.take_in_flight(), Some(Ticket(9)));
        assert_eq!(s.take_in_flight(), None);
        assert_eq!(s.verified + s.rejected, 0, "not counted as settled");
    }

    #[test]
    fn slots_isolate_lanes() {
        let mut slots = SpecSlots::new(SpecConfig { tolerance: 0.0, depth: 1 });
        let qa = vec![0.25f32; 8];
        let qb = vec![0.75f32; 8];
        slots.slot_mut(0).set_in_flight(Ticket(1), qa.clone());
        slots.slot_mut(3).set_in_flight(Ticket(2), qb.clone());
        assert_eq!(slots.n_slots(), 2);
        assert_eq!(slots.in_flight_total(), 2);
        assert!(slots.predicts(0, &qa));
        assert!(!slots.predicts(0, &qb), "lane 0 never predicts lane 3's query");
        // Verifying lane 0 leaves lane 3's prefetch untouched.
        assert_eq!(slots.verify_take(0, &qa), SpecVerdict::Hit(Ticket(1)));
        assert!(slots.has_in_flight(3));
        assert!(!slots.has_in_flight(0));
        // Lane 3 rejects its own mismatch independently.
        assert_eq!(slots.verify_take(3, &qa), SpecVerdict::Reject(Ticket(2)));
        assert_eq!(slots.verified(), 1);
        assert_eq!(slots.rejected(), 1);
        assert!((slots.accuracy() - 0.5).abs() < 1e-12);
        // Untouched slot verifies Idle without creating a lane.
        assert_eq!(slots.verify_take(7, &qa), SpecVerdict::Idle);
        assert_eq!(slots.n_slots(), 2);
    }

    #[test]
    fn take_all_in_flight_drains_every_lane() {
        let mut slots = SpecSlots::new(SpecConfig::default());
        slots.slot_mut(0).set_in_flight(Ticket(1), vec![1.0]);
        slots.slot_mut(1).set_in_flight(Ticket(2), vec![2.0]);
        let mut taken = slots.take_all_in_flight();
        taken.sort_by_key(|t| t.0);
        assert_eq!(taken, vec![Ticket(1), Ticket(2)]);
        assert_eq!(slots.in_flight_total(), 0);
        assert_eq!(slots.issued(), 2, "issue counters survive teardown");
    }
}
