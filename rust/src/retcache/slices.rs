//! Per-tenant slices of one retrieval-cache byte budget.
//!
//! A shared cache is a side channel between tenants: one flooding tenant
//! can evict everyone else's entries and claim the whole budget. The
//! sliced cache gives each tenant its own `RetrievalCache` carved from a
//! single total byte budget — the budget is re-divided evenly whenever a
//! new tenant appears, and shrinking slices pay their evictions
//! immediately (`RetrievalCache::set_capacity`), so the sum of slice
//! budgets never exceeds the configured total.

use std::collections::HashMap;

use super::cache::{CacheConfig, RetrievalCache};

/// Per-tenant retrieval caches over one shared byte budget.
pub struct SlicedCache {
    /// Template config; `capacity_bytes` holds the *total* budget.
    base: CacheConfig,
    slices: HashMap<u32, RetrievalCache>,
}

impl SlicedCache {
    pub fn new(base: CacheConfig) -> SlicedCache {
        SlicedCache { base, slices: HashMap::new() }
    }

    /// The shared budget the slices are carved from.
    pub fn total_capacity(&self) -> usize {
        self.base.capacity_bytes
    }

    pub fn n_tenants(&self) -> usize {
        self.slices.len()
    }

    /// Bytes currently cached across all tenants.
    pub fn bytes(&self) -> usize {
        self.slices.values().map(|c| c.bytes()).sum()
    }

    /// The tenant's slice, created on first sight — creation re-divides
    /// the total budget evenly across all known tenants, shrinking the
    /// existing slices (with immediate evictions) to make room.
    pub fn slice_mut(&mut self, tenant: u32) -> &mut RetrievalCache {
        if !self.slices.contains_key(&tenant) {
            self.slices.insert(tenant, RetrievalCache::new(self.base));
            let per = self.base.capacity_bytes / self.slices.len();
            for c in self.slices.values_mut() {
                c.set_capacity(per);
            }
        }
        self.slices.get_mut(&tenant).unwrap()
    }

    /// Read-only view of a tenant's slice, if the tenant exists.
    pub fn slice(&self, tenant: u32) -> Option<&RetrievalCache> {
        self.slices.get(&tenant)
    }

    /// Aggregate lifetime hit rate across all slices (0 if never queried).
    pub fn hit_rate(&self) -> f64 {
        let (hits, misses) = self
            .slices
            .values()
            .fold((0u64, 0u64), |(h, m), c| (h + c.hits, m + c.misses));
        if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retcache::cache::{CachedEntry, EvictionPolicy};
    use crate::retcache::key::KeyPolicy;

    // Entry size with KeyPolicy::Exact, d=8, k=10: key 32 + ids 80 +
    // dists 40 + overhead 64 = 216 bytes (matches cache.rs tests).
    const E: usize = 216;

    fn base(total: usize) -> CacheConfig {
        CacheConfig {
            capacity_bytes: total,
            policy: EvictionPolicy::Lru,
            key: KeyPolicy::Exact,
        }
    }

    fn entry() -> CachedEntry {
        CachedEntry {
            ids: (0..10).collect(),
            dists: vec![0.5; 10],
            modeled_s: 1e-3,
        }
    }

    fn q(i: usize) -> Vec<f32> {
        vec![i as f32; 8]
    }

    #[test]
    fn new_tenant_rebalances_the_budget_evenly() {
        let mut s = SlicedCache::new(base(4 * E));
        // Sole tenant owns the whole budget.
        for i in 0..4 {
            s.slice_mut(7).insert(&q(i), entry());
        }
        assert_eq!(s.slice(7).unwrap().len(), 4);

        // A second tenant halves every slice; tenant 7 evicts down to 2
        // entries immediately (LRU order: oldest first).
        s.slice_mut(1000);
        assert_eq!(s.n_tenants(), 2);
        let t7 = s.slice(7).unwrap();
        assert_eq!(t7.len(), 2);
        assert!(t7.would_hit(&q(2)) && t7.would_hit(&q(3)));

        // Both slices honor their halves; the total never exceeds budget.
        for i in 0..10 {
            s.slice_mut(1000).insert(&q(i), entry());
            s.slice_mut(7).insert(&q(100 + i), entry());
        }
        assert_eq!(s.slice(1000).unwrap().len(), 2);
        assert_eq!(s.slice(7).unwrap().len(), 2);
        assert!(s.bytes() <= s.total_capacity());
    }

    #[test]
    fn one_tenants_flood_cannot_evict_another() {
        let mut s = SlicedCache::new(base(8 * E));
        // Both tenants exist before the flood, so each owns 4*E.
        s.slice_mut(0).insert(&q(1), entry());
        s.slice_mut(1000);
        for i in 0..1000 {
            s.slice_mut(1000).insert(&q(i), entry());
        }
        assert!(
            s.slice(0).unwrap().would_hit(&q(1)),
            "interactive tenant's entry survived the batch flood"
        );
        assert!(s.slice(1000).unwrap().len() <= 4);
    }

    #[test]
    fn aggregate_hit_rate_spans_tenants() {
        let mut s = SlicedCache::new(base(8 * E));
        s.slice_mut(0).insert(&q(1), entry());
        assert!(s.slice_mut(0).get(&q(1)).is_some()); // hit
        assert!(s.slice_mut(5).get(&q(1)).is_none()); // miss (own slice)
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }
}
