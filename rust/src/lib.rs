//! # Chameleon — heterogeneous & disaggregated RALM serving (reproduction)
//!
//! Rust + JAX + Pallas reproduction of *"Chameleon: a Heterogeneous and
//! Disaggregated Accelerator System for Retrieval-Augmented Language
//! Models"* (Jiang et al., 2023).
//!
//! Three-layer architecture (DESIGN.md):
//! * **L3 (this crate)** — the request path: CPU coordinator, ChamVS
//!   memory nodes and dispatcher, ChamLM worker pool, hardware performance
//!   models, and every substrate the paper depends on (IVF-PQ built from
//!   scratch, K-selection hardware simulators, LogGP network model, ...).
//! * **L2 (python/compile)** — JAX model + search graphs, AOT-lowered to
//!   HLO text in `artifacts/`, loaded here via the PJRT C API
//!   ([`runtime`]). Python never runs at request time.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (PQ LUT/ADC scan, approximate hierarchical top-K, IVF scan,
//!   decode attention).
//!
//! Quick start: see `examples/quickstart.rs`, or run
//! `cargo run --release -- demo`.

pub mod chamlm;
pub mod chamvs;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod hwmodel;
pub mod ivf;
pub mod kselect;
pub mod loadgen;
pub mod net;
pub mod pq;
pub mod report;
pub mod retcache;
pub mod runtime;
pub mod telemetry;
pub mod trace;
pub mod util;

pub use config::{DatasetConfig, ModelConfig, SystemConfig};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
