//! Open-loop load generation for the networked coordinator.
//!
//! Closed-loop benches (each client waits for its reply) famously hide
//! queueing collapse: the arrival rate self-throttles to the service
//! rate. This harness sends requests at their *scheduled* times whether
//! or not earlier replies have arrived, so offered load is independent
//! of server behavior and the latency-vs-load curve shows the real knee
//! (`chameleon loadgen`, `benches/serve_load.rs`, BENCH_serve.json).
//!
//! Workloads are fully deterministic: [`schedule`] derives Poisson or
//! bursty arrival times, Zipf-skewed query indices and request classes
//! from a single seed with no wall-clock input, so two runs with the
//! same seed replay the identical request stream (`--seed`).

use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::net::protocol::{
    Backpressure, FrameReader, Kind, ReadProgress, RetrieveRequest, RetrieveResponse,
};
use crate::retcache::workload::zipf_stream;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Request arrival process at a target mean rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Memoryless: exponential inter-arrival gaps at `qps`.
    Poisson,
    /// On/off bursts: all arrivals compress into the first
    /// `duty` fraction of each `period_s` window (Poisson within the
    /// burst at `qps / duty`), preserving the long-run mean rate.
    Bursty { period_s: f64, duty: f64 },
}

/// Request class mix: interactive requests fetch next-token ids,
/// batch-class requests ask for whole chunks (bigger replies, the
/// paper's throughput-oriented RALM consumers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqClass {
    Interactive,
    Batch,
}

impl ReqClass {
    pub fn name(self) -> &'static str {
        match self {
            ReqClass::Interactive => "interactive",
            ReqClass::Batch => "batch",
        }
    }
}

/// Deterministic workload description.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Offered load (mean requests/second).
    pub qps: f64,
    pub n_requests: usize,
    pub arrival: Arrival,
    /// Zipf skew over the query pool (0.0 = uniform).
    pub zipf_alpha: f64,
    /// Distinct queries in the pool.
    pub n_unique: usize,
    /// Fraction of requests in the batch class.
    pub batch_fraction: f64,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            qps: 200.0,
            n_requests: 400,
            arrival: Arrival::Poisson,
            zipf_alpha: 0.99,
            n_unique: 64,
            batch_fraction: 0.2,
            seed: 42,
        }
    }
}

/// A materialized request stream: arrival offsets (seconds from run
/// start, ascending), query-pool indices and classes, all index-aligned.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub arrivals_s: Vec<f64>,
    pub query_idx: Vec<usize>,
    pub classes: Vec<ReqClass>,
}

impl Schedule {
    pub fn len(&self) -> usize {
        self.arrivals_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_s.is_empty()
    }

    /// Scheduled span from first to last arrival.
    pub fn span_s(&self) -> f64 {
        self.arrivals_s.last().copied().unwrap_or(0.0)
    }
}

/// Materialize the deterministic request stream for `cfg`. Pure: no
/// wall clock, no global state — same config, same schedule.
pub fn schedule(cfg: &LoadgenConfig) -> Schedule {
    assert!(cfg.qps > 0.0, "qps must be positive");
    assert!(cfg.n_unique > 0);
    let mut root = Rng::new(cfg.seed);
    let mut arr_rng = root.fork(1);
    let mut class_rng = root.fork(2);

    // Poisson arrivals at the burst-local rate, then (for bursty) warp
    // the timeline so arrivals land only inside on-windows.
    let local_rate = match cfg.arrival {
        Arrival::Poisson => cfg.qps,
        Arrival::Bursty { duty, .. } => {
            assert!(duty > 0.0 && duty <= 1.0, "duty must be in (0, 1]");
            cfg.qps / duty
        }
    };
    let mut t = 0.0f64;
    let arrivals_s: Vec<f64> = (0..cfg.n_requests)
        .map(|_| {
            let u = arr_rng.f64();
            t += -(1.0 - u).ln() / local_rate;
            match cfg.arrival {
                Arrival::Poisson => t,
                Arrival::Bursty { period_s, duty } => {
                    let on = period_s * duty;
                    let window = (t / on).floor();
                    window * period_s + (t - window * on)
                }
            }
        })
        .collect();

    let query_idx = zipf_stream(
        cfg.n_unique,
        cfg.zipf_alpha.max(0.0),
        cfg.n_requests,
        cfg.seed ^ 0x51ff_c0de,
    );
    let classes = (0..cfg.n_requests)
        .map(|_| {
            if class_rng.f64() < cfg.batch_fraction {
                ReqClass::Batch
            } else {
                ReqClass::Interactive
            }
        })
        .collect();
    Schedule { arrivals_s, query_idx, classes }
}

/// Outcome of one open-loop run at a fixed offered load.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered_qps: f64,
    pub sent: usize,
    pub received: usize,
    /// Requests the server refused with an explicit `Backpressure` frame
    /// (admission control). Accounted, not lost: every sent request is
    /// either received or shed when the server is healthy.
    pub shed: usize,
    /// Wall seconds from run start until the last reply (or timeout).
    pub wall_s: f64,
    /// Completed requests per second of wall time.
    pub goodput_qps: f64,
    /// Per-request latency measured from the *scheduled* arrival (so
    /// sender backlog under overload counts, as it should open-loop).
    pub latency: Summary,
    pub interactive: Option<Summary>,
    pub batch: Option<Summary>,
}

/// Drive `sched` against a live coordinator at `addr`, round-robining
/// requests over `conns` connections. Each connection gets a writer
/// thread (sends at scheduled times, never waits for replies) and a
/// reader thread (drains replies, stamps completion). `deadline` bounds
/// how long we wait for stragglers after the last send.
pub fn drive(
    addr: SocketAddr,
    queries: &[Vec<f32>],
    k: usize,
    sched: &Schedule,
    conns: usize,
    deadline: Duration,
) -> Result<OpenLoopReport> {
    assert!(conns > 0);
    assert!(!sched.is_empty(), "empty schedule");
    assert!(!queries.is_empty());
    let n = sched.len();

    // Completion stamps, nanos since t0 (0 = not yet answered).
    let done_ns: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    // Admission-control sheds (1 = the server answered `Backpressure`).
    let shed_flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let streams: Vec<TcpStream> = (0..conns)
        .map(|_| {
            let s = TcpStream::connect(addr).context("connecting to coordinator")?;
            s.set_nodelay(true)?;
            Ok(s)
        })
        .collect::<Result<_>>()?;

    let t0 = Instant::now();
    let mut sent_per_conn = vec![0usize; conns];
    for i in 0..n {
        sent_per_conn[i % conns] += 1;
    }

    std::thread::scope(|scope| -> Result<()> {
        for (c, stream) in streams.iter().enumerate() {
            let expect = sent_per_conn[c];
            if expect == 0 {
                continue;
            }
            // Writer: fire requests at their scheduled offsets.
            let mut wtr = stream.try_clone()?;
            let done_ns = &done_ns;
            scope.spawn(move || {
                for i in (c..n).step_by(conns) {
                    let at = Duration::from_secs_f64(sched.arrivals_s[i]);
                    if let Some(wait) = at.checked_sub(t0.elapsed()) {
                        std::thread::sleep(wait);
                    }
                    let class = sched.classes[i];
                    let req = RetrieveRequest {
                        query_id: i as u64,
                        // Class-segregated gpu ids keep speculation slots
                        // and per-source stats separable downstream.
                        gpu_id: match class {
                            ReqClass::Interactive => c as u32,
                            ReqClass::Batch => 1000 + c as u32,
                        },
                        query: queries[sched.query_idx[i] % queries.len()].clone(),
                        lists: Vec::new(),
                        k: k as u32,
                        want_chunks: class == ReqClass::Batch,
                    };
                    if req.encode().write_to(&mut wtr).is_err() {
                        return; // server closed the connection
                    }
                }
            });
            // Reader: drain replies until all expected or deadline. A
            // FrameReader keeps partial frames buffered across read
            // timeouts — a slow server mid-frame is idleness, not desync.
            let mut rdr = stream.try_clone()?;
            stream.set_read_timeout(Some(Duration::from_millis(100)))?;
            let shed_flags = &shed_flags;
            scope.spawn(move || {
                let mut frames = FrameReader::new();
                let mut got = 0usize;
                while got < expect && t0.elapsed() < deadline {
                    match frames.poll(&mut rdr) {
                        Ok(ReadProgress::Frame(f)) => {
                            // A shed is a reply too: stamp it so the
                            // accounting (received + shed == sent) holds
                            // and the reader doesn't wait on it forever.
                            if f.kind == Kind::Backpressure {
                                let Ok(bp) = Backpressure::decode(&f) else { break };
                                let i = bp.query_id as usize;
                                if i < n {
                                    shed_flags[i].store(1, Ordering::Relaxed);
                                    got += 1;
                                }
                                continue;
                            }
                            let Ok(resp) = RetrieveResponse::decode(&f) else { break };
                            let i = resp.query_id as usize;
                            if i < n {
                                done_ns[i].store(
                                    t0.elapsed().as_nanos().max(1) as u64,
                                    Ordering::Relaxed,
                                );
                                got += 1;
                            }
                        }
                        Ok(ReadProgress::Idle) => continue,
                        Ok(ReadProgress::Closed) | Err(_) => break,
                    }
                }
            });
        }
        Ok(())
    })?;

    // Aggregate: latency from scheduled arrival to completion stamp.
    let mut lat = Vec::new();
    let mut lat_interactive = Vec::new();
    let mut lat_batch = Vec::new();
    let mut last_done = 0.0f64;
    for i in 0..n {
        let ns = done_ns[i].load(Ordering::Relaxed);
        if ns == 0 {
            continue;
        }
        let done_s = ns as f64 * 1e-9;
        last_done = last_done.max(done_s);
        let l = (done_s - sched.arrivals_s[i]).max(0.0);
        lat.push(l);
        match sched.classes[i] {
            ReqClass::Interactive => lat_interactive.push(l),
            ReqClass::Batch => lat_batch.push(l),
        }
    }
    let received = lat.len();
    let shed = shed_flags.iter().filter(|f| f.load(Ordering::Relaxed) != 0).count();
    anyhow::ensure!(received > 0, "open-loop run received no replies");
    let wall_s = last_done.max(sched.span_s()).max(1e-9);
    Ok(OpenLoopReport {
        offered_qps: n as f64 / sched.span_s().max(1e-9),
        sent: n,
        received,
        shed,
        wall_s,
        goodput_qps: received as f64 / wall_s,
        latency: Summary::of(&lat),
        interactive: if lat_interactive.is_empty() {
            None
        } else {
            Some(Summary::of(&lat_interactive))
        },
        batch: if lat_batch.is_empty() { None } else { Some(Summary::of(&lat_batch)) },
    })
}

/// The measured saturation knee of an offered-load sweep: the highest
/// goodput any offered load sustained.
pub fn measured_knee_qps(sweep: &[OpenLoopReport]) -> f64 {
    sweep.iter().map(|r| r.goodput_qps).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let cfg = LoadgenConfig { seed: 7, ..Default::default() };
        assert_eq!(schedule(&cfg), schedule(&cfg));
        let other = schedule(&LoadgenConfig { seed: 8, ..Default::default() });
        assert_ne!(schedule(&cfg), other);
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let cfg = LoadgenConfig {
            qps: 500.0,
            n_requests: 20_000,
            zipf_alpha: 0.0,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let rate = s.len() as f64 / s.span_s();
        assert!((rate / cfg.qps - 1.0).abs() < 0.05, "rate {rate}");
        // Ascending arrivals.
        assert!(s.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn bursty_compresses_into_on_windows() {
        let (period_s, duty) = (0.1, 0.25);
        let cfg = LoadgenConfig {
            qps: 1000.0,
            n_requests: 10_000,
            arrival: Arrival::Bursty { period_s, duty },
            ..Default::default()
        };
        let s = schedule(&cfg);
        // Every arrival lands inside an on-window, and the long-run
        // rate still matches the target.
        for &t in &s.arrivals_s {
            let phase = t.rem_euclid(period_s);
            assert!(phase <= period_s * duty + 1e-9, "arrival at off-phase {phase}");
        }
        let rate = s.len() as f64 / s.span_s();
        assert!((rate / cfg.qps - 1.0).abs() < 0.1, "rate {rate}");
        assert!(s.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn zipf_skew_prefers_low_indices() {
        let cfg = LoadgenConfig {
            zipf_alpha: 1.2,
            n_unique: 100,
            n_requests: 10_000,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let head = s.query_idx.iter().filter(|&&i| i < 10).count();
        assert!(head > s.len() / 2, "head hits {head}");
        assert!(s.query_idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn class_mix_matches_fraction() {
        let cfg = LoadgenConfig {
            batch_fraction: 0.3,
            n_requests: 10_000,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let batch = s.classes.iter().filter(|&&c| c == ReqClass::Batch).count();
        let frac = batch as f64 / s.len() as f64;
        assert!((frac - 0.3).abs() < 0.03, "batch fraction {frac}");
    }

    #[test]
    fn knee_is_max_goodput() {
        let mk = |g: f64| OpenLoopReport {
            offered_qps: g,
            sent: 1,
            received: 1,
            shed: 0,
            wall_s: 1.0,
            goodput_qps: g,
            latency: Summary::of(&[0.001]),
            interactive: None,
            batch: None,
        };
        assert_eq!(measured_knee_qps(&[mk(10.0), mk(35.0), mk(20.0)]), 35.0);
    }
}
