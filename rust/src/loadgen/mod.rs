//! Open-loop load generation for the networked coordinator.
//!
//! Closed-loop benches (each client waits for its reply) famously hide
//! queueing collapse: the arrival rate self-throttles to the service
//! rate. This harness sends requests at their *scheduled* times whether
//! or not earlier replies have arrived, so offered load is independent
//! of server behavior and the latency-vs-load curve shows the real knee
//! (`chameleon loadgen`, `benches/serve_load.rs`, BENCH_serve.json).
//!
//! Workloads are fully deterministic: [`schedule`] derives Poisson or
//! bursty arrival times, Zipf-skewed query indices and request classes
//! from a single seed with no wall-clock input, so two runs with the
//! same seed replay the identical request stream (`--seed`).

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::admission::ShedReason;
use crate::net::protocol::{
    Backpressure, FrameReader, Kind, ReadProgress, RetrieveRequest, RetrieveResponse,
};
use crate::retcache::workload::zipf_stream;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Request arrival process at a target mean rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Memoryless: exponential inter-arrival gaps at `qps`.
    Poisson,
    /// On/off bursts: all arrivals compress into the first
    /// `duty` fraction of each `period_s` window (Poisson within the
    /// burst at `qps / duty`), preserving the long-run mean rate.
    Bursty { period_s: f64, duty: f64 },
}

/// Request class mix: interactive requests fetch next-token ids,
/// batch-class requests ask for whole chunks (bigger replies, the
/// paper's throughput-oriented RALM consumers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqClass {
    Interactive,
    Batch,
}

impl ReqClass {
    pub fn name(self) -> &'static str {
        match self {
            ReqClass::Interactive => "interactive",
            ReqClass::Batch => "batch",
        }
    }
}

/// Deterministic workload description.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Offered load (mean requests/second).
    pub qps: f64,
    pub n_requests: usize,
    pub arrival: Arrival,
    /// Zipf skew over the query pool (0.0 = uniform).
    pub zipf_alpha: f64,
    /// Distinct queries in the pool.
    pub n_unique: usize,
    /// Fraction of requests in the batch class.
    pub batch_fraction: f64,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            qps: 200.0,
            n_requests: 400,
            arrival: Arrival::Poisson,
            zipf_alpha: 0.99,
            n_unique: 64,
            batch_fraction: 0.2,
            seed: 42,
        }
    }
}

/// A materialized request stream: arrival offsets (seconds from run
/// start, ascending), query-pool indices and classes, all index-aligned.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub arrivals_s: Vec<f64>,
    pub query_idx: Vec<usize>,
    pub classes: Vec<ReqClass>,
}

impl Schedule {
    pub fn len(&self) -> usize {
        self.arrivals_s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_s.is_empty()
    }

    /// Scheduled span from first to last arrival.
    pub fn span_s(&self) -> f64 {
        self.arrivals_s.last().copied().unwrap_or(0.0)
    }
}

/// Materialize the deterministic request stream for `cfg`. Pure: no
/// wall clock, no global state — same config, same schedule.
pub fn schedule(cfg: &LoadgenConfig) -> Schedule {
    assert!(cfg.qps > 0.0, "qps must be positive");
    assert!(cfg.n_unique > 0);
    let mut root = Rng::new(cfg.seed);
    let mut arr_rng = root.fork(1);
    let mut class_rng = root.fork(2);

    // Poisson arrivals at the burst-local rate, then (for bursty) warp
    // the timeline so arrivals land only inside on-windows.
    let local_rate = match cfg.arrival {
        Arrival::Poisson => cfg.qps,
        Arrival::Bursty { duty, .. } => {
            assert!(duty > 0.0 && duty <= 1.0, "duty must be in (0, 1]");
            cfg.qps / duty
        }
    };
    let mut t = 0.0f64;
    let arrivals_s: Vec<f64> = (0..cfg.n_requests)
        .map(|_| {
            let u = arr_rng.f64();
            t += -(1.0 - u).ln() / local_rate;
            match cfg.arrival {
                Arrival::Poisson => t,
                Arrival::Bursty { period_s, duty } => {
                    let on = period_s * duty;
                    let window = (t / on).floor();
                    window * period_s + (t - window * on)
                }
            }
        })
        .collect();

    let query_idx = zipf_stream(
        cfg.n_unique,
        cfg.zipf_alpha.max(0.0),
        cfg.n_requests,
        cfg.seed ^ 0x51ff_c0de,
    );
    let classes = (0..cfg.n_requests)
        .map(|_| {
            if class_rng.f64() < cfg.batch_fraction {
                ReqClass::Batch
            } else {
                ReqClass::Interactive
            }
        })
        .collect();
    Schedule { arrivals_s, query_idx, classes }
}

/// Client-side retry behavior on `Backpressure` sheds.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Max re-sends per request (0 = a shed is final, the legacy
    /// behavior).
    pub max_retries: u32,
    /// Backoff floor; the server's `retry_after_us` hint raises it,
    /// never lowers it.
    pub base_backoff: Duration,
    /// Cap on the exponentially growing backoff.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff: Duration::from_micros(200),
            max_backoff: Duration::from_millis(50),
        }
    }
}

/// Per-run knobs beyond the schedule itself.
#[derive(Clone, Copy, Debug, Default)]
pub struct DriveOptions {
    /// End-to-end deadline budget stamped on every request, in
    /// microseconds (0 = unbounded). The coordinator sheds
    /// queue-expired requests and serves deadline-clipped partials
    /// (under its degraded policy) against this budget.
    pub deadline_us: u64,
    /// Backoff-and-retry behavior on admission sheds.
    pub retry: RetryPolicy,
}

/// Outcome of one open-loop run at a fixed offered load.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered_qps: f64,
    pub sent: usize,
    pub received: usize,
    /// Requests the server refused with an explicit `Backpressure` frame
    /// (admission control). Accounted, not lost: every sent request is
    /// either received or shed when the server is healthy.
    pub shed: usize,
    /// Received replies that were degraded partials (coverage < 1.0).
    /// `complete + partial + shed == sent` when every reply made it
    /// back before the run deadline.
    pub partial: usize,
    /// Backoff re-sends after `Backpressure` (0 unless retries are on).
    pub retries: usize,
    /// Requests that were shed at least once and still completed after
    /// backing off — the retry machinery's success count.
    pub retry_success: usize,
    /// Wall seconds from run start until the last reply (or timeout).
    pub wall_s: f64,
    /// Completed requests per second of wall time.
    pub goodput_qps: f64,
    /// Per-request latency measured from the *scheduled* arrival (so
    /// sender backlog under overload counts, as it should open-loop).
    pub latency: Summary,
    pub interactive: Option<Summary>,
    pub batch: Option<Summary>,
}

impl OpenLoopReport {
    /// Replies that covered every shard.
    pub fn complete(&self) -> usize {
        self.received - self.partial
    }

    /// Fraction of ever-shed requests that a backoff retry rescued;
    /// 1.0 when nothing was ever shed.
    pub fn retry_success_rate(&self) -> f64 {
        let ever_shed = self.retry_success + self.shed;
        if ever_shed == 0 {
            return 1.0;
        }
        self.retry_success as f64 / ever_shed as f64
    }
}

/// Drive `sched` against a live coordinator at `addr`, round-robining
/// requests over `conns` connections. Each connection gets a writer
/// thread (sends at scheduled times, never waits for replies) and a
/// reader thread (drains replies, stamps completion). `deadline` bounds
/// how long we wait for stragglers after the last send.
pub fn drive(
    addr: SocketAddr,
    queries: &[Vec<f32>],
    k: usize,
    sched: &Schedule,
    conns: usize,
    deadline: Duration,
) -> Result<OpenLoopReport> {
    drive_opts(addr, queries, k, sched, conns, deadline, &DriveOptions::default())
}

/// [`drive`] with per-run options: an end-to-end deadline budget stamped
/// on every request, and capped-exponential backoff retries on
/// `Backpressure` sheds (honoring the server's `retry_after_us` hint).
/// A `DeadlineExpired` shed is never retried — its budget is gone.
pub fn drive_opts(
    addr: SocketAddr,
    queries: &[Vec<f32>],
    k: usize,
    sched: &Schedule,
    conns: usize,
    deadline: Duration,
    opts: &DriveOptions,
) -> Result<OpenLoopReport> {
    assert!(conns > 0);
    assert!(!sched.is_empty(), "empty schedule");
    assert!(!queries.is_empty());
    let n = sched.len();

    // Completion stamps, nanos since t0 (0 = not yet answered).
    let done_ns: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    // Admission-control sheds (1 = the server's `Backpressure` verdict
    // stood — retries, if any, were also shed or the budget ran out).
    let shed_flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    // Degraded partial replies (coverage < 1.0).
    let partial_flags: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let retries_sent = AtomicU64::new(0);
    let retry_ok = AtomicU64::new(0);
    let streams: Vec<TcpStream> = (0..conns)
        .map(|_| {
            let s = TcpStream::connect(addr).context("connecting to coordinator")?;
            s.set_nodelay(true)?;
            Ok(s)
        })
        .collect::<Result<_>>()?;
    // Per-connection retry queues (due time, request index) — filled by
    // the reader on a retryable shed, drained by the writer (replies are
    // per-connection FIFO, so the retry must ride its original stream).
    let retryqs: Vec<Mutex<Vec<(Instant, usize)>>> =
        (0..conns).map(|_| Mutex::new(Vec::new())).collect();
    let readers_live: Vec<AtomicU64> = (0..conns).map(|_| AtomicU64::new(1)).collect();

    let t0 = Instant::now();
    let mut sent_per_conn = vec![0usize; conns];
    for i in 0..n {
        sent_per_conn[i % conns] += 1;
    }

    std::thread::scope(|scope| -> Result<()> {
        for (c, stream) in streams.iter().enumerate() {
            let expect = sent_per_conn[c];
            if expect == 0 {
                readers_live[c].store(0, Ordering::Relaxed);
                continue;
            }
            let mk_req = move |i: usize| {
                let class = sched.classes[i];
                RetrieveRequest {
                    query_id: i as u64,
                    // Class-segregated gpu ids keep speculation slots
                    // and per-source stats separable downstream.
                    gpu_id: match class {
                        ReqClass::Interactive => c as u32,
                        ReqClass::Batch => 1000 + c as u32,
                    },
                    query: queries[sched.query_idx[i] % queries.len()].clone(),
                    lists: Vec::new(),
                    k: k as u32,
                    want_chunks: class == ReqClass::Batch,
                    deadline_us: opts.deadline_us,
                }
            };
            // Writer: fire requests at their scheduled offsets, weaving
            // due retries into the gaps; after the schedule drains it
            // keeps serving retries until its reader finishes.
            let mut wtr = stream.try_clone()?;
            let retryq = &retryqs[c];
            let reader_live = &readers_live[c];
            let retries_sent = &retries_sent;
            scope.spawn(move || {
                let fire_due = |wtr: &mut TcpStream| -> bool {
                    let due: Vec<usize> = {
                        let mut q = retryq.lock().unwrap();
                        let now = Instant::now();
                        let mut d = Vec::new();
                        let mut j = 0;
                        while j < q.len() {
                            if q[j].0 <= now {
                                d.push(q.swap_remove(j).1);
                            } else {
                                j += 1;
                            }
                        }
                        d
                    };
                    for i in due {
                        retries_sent.fetch_add(1, Ordering::Relaxed);
                        if mk_req(i).encode().write_to(wtr).is_err() {
                            return false;
                        }
                    }
                    true
                };
                for i in (c..n).step_by(conns) {
                    let at = Duration::from_secs_f64(sched.arrivals_s[i]);
                    // Sleep in short slices so a due retry doesn't wait
                    // out a long inter-arrival gap.
                    while let Some(wait) = at.checked_sub(t0.elapsed()) {
                        if !fire_due(&mut wtr) {
                            return;
                        }
                        std::thread::sleep(wait.min(Duration::from_millis(5)));
                    }
                    if !fire_due(&mut wtr) || mk_req(i).encode().write_to(&mut wtr).is_err()
                    {
                        return; // server closed the connection
                    }
                }
                while reader_live.load(Ordering::Relaxed) != 0 && t0.elapsed() < deadline
                {
                    if !fire_due(&mut wtr) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            });
            // Reader: drain replies until all expected or deadline. A
            // FrameReader keeps partial frames buffered across read
            // timeouts — a slow server mid-frame is idleness, not desync.
            let mut rdr = stream.try_clone()?;
            stream.set_read_timeout(Some(Duration::from_millis(100)))?;
            let shed_flags = &shed_flags;
            let partial_flags = &partial_flags;
            let done_ns = &done_ns;
            let retry = opts.retry;
            let retry_ok = &retry_ok;
            scope.spawn(move || {
                let mut frames = FrameReader::new();
                let mut got = 0usize;
                let mut expect = expect;
                // Shed count per request, for backoff growth and the
                // retry budget (indices are conn-partitioned, so this
                // reader sees every reply for its requests).
                let mut attempts: HashMap<usize, u32> = HashMap::new();
                while got < expect && t0.elapsed() < deadline {
                    match frames.poll(&mut rdr) {
                        Ok(ReadProgress::Frame(f)) => {
                            // A shed is a reply too: stamp or retry it so
                            // the accounting (complete + partial + shed
                            // == sent) holds and the reader doesn't wait
                            // on it forever.
                            if f.kind == Kind::Backpressure {
                                let Ok(bp) = Backpressure::decode(&f) else { break };
                                let i = bp.query_id as usize;
                                if i >= n {
                                    continue;
                                }
                                got += 1;
                                let a = attempts.get(&i).copied().unwrap_or(0);
                                let expired =
                                    bp.reason == ShedReason::DeadlineExpired.code();
                                if expired || a >= retry.max_retries {
                                    shed_flags[i].store(1, Ordering::Relaxed);
                                    continue;
                                }
                                // Capped exponential backoff, floored at
                                // the server's retry hint.
                                let hint = Duration::from_micros(bp.retry_after_us);
                                let backoff = retry
                                    .base_backoff
                                    .max(hint)
                                    .saturating_mul(1u32 << a.min(16))
                                    .min(retry.max_backoff);
                                attempts.insert(i, a + 1);
                                retryq
                                    .lock()
                                    .unwrap()
                                    .push((Instant::now() + backoff, i));
                                expect += 1; // the retry owes one more reply
                                continue;
                            }
                            let Ok(resp) = RetrieveResponse::decode(&f) else { break };
                            let i = resp.query_id as usize;
                            if i < n {
                                done_ns[i].store(
                                    t0.elapsed().as_nanos().max(1) as u64,
                                    Ordering::Relaxed,
                                );
                                if resp.is_partial() {
                                    partial_flags[i].store(1, Ordering::Relaxed);
                                }
                                if attempts.contains_key(&i) {
                                    retry_ok.fetch_add(1, Ordering::Relaxed);
                                }
                                got += 1;
                            }
                        }
                        Ok(ReadProgress::Idle) => continue,
                        Ok(ReadProgress::Closed) | Err(_) => break,
                    }
                }
                reader_live.store(0, Ordering::Relaxed);
            });
        }
        Ok(())
    })?;

    // Aggregate: latency from scheduled arrival to completion stamp.
    let mut lat = Vec::new();
    let mut lat_interactive = Vec::new();
    let mut lat_batch = Vec::new();
    let mut last_done = 0.0f64;
    for i in 0..n {
        let ns = done_ns[i].load(Ordering::Relaxed);
        if ns == 0 {
            continue;
        }
        let done_s = ns as f64 * 1e-9;
        last_done = last_done.max(done_s);
        let l = (done_s - sched.arrivals_s[i]).max(0.0);
        lat.push(l);
        match sched.classes[i] {
            ReqClass::Interactive => lat_interactive.push(l),
            ReqClass::Batch => lat_batch.push(l),
        }
    }
    let received = lat.len();
    let shed = shed_flags.iter().filter(|f| f.load(Ordering::Relaxed) != 0).count();
    let partial =
        partial_flags.iter().filter(|f| f.load(Ordering::Relaxed) != 0).count();
    anyhow::ensure!(received > 0, "open-loop run received no replies");
    let wall_s = last_done.max(sched.span_s()).max(1e-9);
    Ok(OpenLoopReport {
        offered_qps: n as f64 / sched.span_s().max(1e-9),
        sent: n,
        received,
        shed,
        partial,
        retries: retries_sent.load(Ordering::Relaxed) as usize,
        retry_success: retry_ok.load(Ordering::Relaxed) as usize,
        wall_s,
        goodput_qps: received as f64 / wall_s,
        latency: Summary::of(&lat),
        interactive: if lat_interactive.is_empty() {
            None
        } else {
            Some(Summary::of(&lat_interactive))
        },
        batch: if lat_batch.is_empty() { None } else { Some(Summary::of(&lat_batch)) },
    })
}

/// The measured saturation knee of an offered-load sweep: the highest
/// goodput any offered load sustained.
pub fn measured_knee_qps(sweep: &[OpenLoopReport]) -> f64 {
    sweep.iter().map(|r| r.goodput_qps).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let cfg = LoadgenConfig { seed: 7, ..Default::default() };
        assert_eq!(schedule(&cfg), schedule(&cfg));
        let other = schedule(&LoadgenConfig { seed: 8, ..Default::default() });
        assert_ne!(schedule(&cfg), other);
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let cfg = LoadgenConfig {
            qps: 500.0,
            n_requests: 20_000,
            zipf_alpha: 0.0,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let rate = s.len() as f64 / s.span_s();
        assert!((rate / cfg.qps - 1.0).abs() < 0.05, "rate {rate}");
        // Ascending arrivals.
        assert!(s.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn bursty_compresses_into_on_windows() {
        let (period_s, duty) = (0.1, 0.25);
        let cfg = LoadgenConfig {
            qps: 1000.0,
            n_requests: 10_000,
            arrival: Arrival::Bursty { period_s, duty },
            ..Default::default()
        };
        let s = schedule(&cfg);
        // Every arrival lands inside an on-window, and the long-run
        // rate still matches the target.
        for &t in &s.arrivals_s {
            let phase = t.rem_euclid(period_s);
            assert!(phase <= period_s * duty + 1e-9, "arrival at off-phase {phase}");
        }
        let rate = s.len() as f64 / s.span_s();
        assert!((rate / cfg.qps - 1.0).abs() < 0.1, "rate {rate}");
        assert!(s.arrivals_s.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn zipf_skew_prefers_low_indices() {
        let cfg = LoadgenConfig {
            zipf_alpha: 1.2,
            n_unique: 100,
            n_requests: 10_000,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let head = s.query_idx.iter().filter(|&&i| i < 10).count();
        assert!(head > s.len() / 2, "head hits {head}");
        assert!(s.query_idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn class_mix_matches_fraction() {
        let cfg = LoadgenConfig {
            batch_fraction: 0.3,
            n_requests: 10_000,
            ..Default::default()
        };
        let s = schedule(&cfg);
        let batch = s.classes.iter().filter(|&&c| c == ReqClass::Batch).count();
        let frac = batch as f64 / s.len() as f64;
        assert!((frac - 0.3).abs() < 0.03, "batch fraction {frac}");
    }

    #[test]
    fn knee_is_max_goodput() {
        let mk = |g: f64| OpenLoopReport {
            offered_qps: g,
            sent: 1,
            received: 1,
            shed: 0,
            partial: 0,
            retries: 0,
            retry_success: 0,
            wall_s: 1.0,
            goodput_qps: g,
            latency: Summary::of(&[0.001]),
            interactive: None,
            batch: None,
        };
        assert_eq!(measured_knee_qps(&[mk(10.0), mk(35.0), mk(20.0)]), 35.0);
    }
}
