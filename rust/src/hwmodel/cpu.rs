//! CPU vector-search performance model (the Faiss baseline of Fig 9).
//!
//! Calibration comes from the paper's own measurements (Sec 2.3): PQ-code
//! scanning peaks at ~1 GB/s/core even SIMD-optimized (1.2 GB/s on a
//! Xeon 8259CL), because of per-code cache lookups and dependent
//! accumulations. The baseline testbed is an 8-core EPYC 7313 (Sec 6.1).

/// A CPU server model for vector search.
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    pub n_cores: usize,
    /// PQ-code bytes scanned per second per core (paper: ~1 GB/s).
    pub scan_bytes_per_core: f64,
    /// Dense FLOP/s per core for LUT construction / index scan.
    pub flops_per_core: f64,
    /// Package power under load (W) for Table 5.
    pub power_w: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        CpuModel {
            n_cores: 8,
            scan_bytes_per_core: 1.0e9,
            flops_per_core: 2.5e10, // ~3 GHz * 8-wide FMA
            power_w: 125.0,
        }
    }
}

impl CpuModel {
    /// Latency of the PQ-code scan phase for one query.
    pub fn scan_latency(&self, n_codes: usize, m: usize) -> f64 {
        let bytes = (n_codes * m) as f64;
        bytes / (self.scan_bytes_per_core * self.n_cores as f64)
    }

    /// Latency of scanning the IVF index (query x nlist centroid dists).
    ///
    /// Single-core: Faiss parallelizes across queries, not within one
    /// query's coarse scan — the regime the paper's b=1 latencies measure.
    /// This is exactly why its GPU index scan helps (Fig 9: FPGA-GPU is
    /// 1.04-3.87x over FPGA-CPU).
    pub fn index_scan_latency(&self, nlist: usize, d: usize) -> f64 {
        let flops = 2.0 * nlist as f64 * d as f64;
        flops / self.flops_per_core
    }

    /// LUT construction for one query: m*256 sub-distances of dsub MACs,
    /// one table per probed list (residual IVF-PQ).
    pub fn lut_latency(&self, m: usize, dsub: usize, nprobe: usize) -> f64 {
        let flops = 3.0 * (m * 256 * dsub * nprobe) as f64;
        flops / (self.flops_per_core * self.n_cores as f64)
    }

    /// Full CPU-only completion time for a batch of `b` queries (paper's
    /// `CPU` system): index scan + LUT + PQ scan.
    ///
    /// Batching matters on CPU because a *single* query's PQ scan cannot
    /// use all cores effectively (dependent lookups thrash shared cache;
    /// Faiss caps out around two cores of useful intra-query parallelism),
    /// while a batch spreads queries across cores — this is why Table 5's
    /// CPU energy/query improves with batch size.
    pub fn query_latency(
        &self,
        b: usize,
        n_codes: usize,
        m: usize,
        dsub: usize,
        nlist: usize,
        nprobe: usize,
    ) -> f64 {
        let eff_cores = self.n_cores.min(2 * b) as f64;
        let scan =
            (b * n_codes * m) as f64 / (self.scan_bytes_per_core * eff_cores);
        // Index scan + LUT build are per-query single-core jobs that run
        // in parallel across the batch: ceil(b / cores) rounds.
        let per_query =
            self.index_scan_latency(nlist, dsub * m) + self.lut_latency(m, dsub, nprobe);
        let rounds = (b as f64 / self.n_cores as f64).ceil();
        scan + rounds * per_query
    }

    /// Queries per second at a given batch size.
    pub fn throughput(&self, n_codes: usize, m: usize, dsub: usize, nlist: usize, nprobe: usize) -> f64 {
        let b = self.n_cores;
        b as f64 / self.query_latency(b, n_codes, m, dsub, nlist, nprobe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scan_dominates_at_scale() {
        // SIFT paper scale: ~1e6 codes x 16 B at 8 GB/s = ~2 ms; the index
        // + LUT phases must be well under that.
        let c = CpuModel::default();
        let codes = (1e9 * 32.0 / 32768.0) as usize;
        let scan = c.scan_latency(codes, 16);
        let idx = c.index_scan_latency(32768, 128);
        let lut = c.lut_latency(16, 8, 32);
        assert!(scan > 1e-3, "{scan}");
        assert!(idx < scan && lut < scan, "{idx} {lut} vs {scan}");
    }

    #[test]
    fn fpga_speedup_band_matches_fig9() {
        // Fig 9's overall FPGA-over-CPU range is 1.36x (batched FPGA-CPU)
        // to 23.72x (b=1 FPGA-GPU); the scan-stage comparison at b=1 must
        // land inside it.
        let c = CpuModel::default();
        let f = super::super::fpga::FpgaModel::default();
        let codes = (1e9 * 32.0 / 32768.0) as usize;
        let cpu = c.query_latency(1, codes, 16, 8, 32768, 32);
        let fpga = f.query_latency(codes, 16, 32, 100).total();
        let speedup = cpu / fpga;
        assert!(speedup > 1.36 && speedup < 23.72, "speedup {speedup}");
    }

    #[test]
    fn batching_amortizes_per_query_cost() {
        // Table 5 behaviour: per-query time (and thus energy) improves
        // from b=1 to b=16 as the batch saturates the cores, then growth
        // is linear (bandwidth-bound).
        let c = CpuModel::default();
        let per = |b: usize| c.query_latency(b, 100_000, 32, 16, 1024, 32) / b as f64;
        assert!(per(4) < per(1), "{} !< {}", per(4), per(1));
        assert!(per(16) <= per(4) * 1.01);
        // Beyond core saturation, batch time grows linearly.
        let t16 = c.query_latency(16, 100_000, 32, 16, 1024, 32);
        let t32 = c.query_latency(32, 100_000, 32, 16, 1024, 32);
        assert!((t32 / t16 - 2.0).abs() < 0.2, "{t16} {t32}");
    }
}
