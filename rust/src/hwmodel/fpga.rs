//! Cycle-level model of the ChamVS near-memory accelerator
//! (paper Sec 4, Fig 4/5) and its U250 resource footprint (Table 4).

use crate::kselect::HierarchicalConfig;

/// Alveo U250 resource pools (paper Sec 6.2).
pub const U250_LUT: f64 = 1_728_000.0;
pub const U250_FF: f64 = 3_456_000.0;
pub const U250_BRAM: f64 = 2_688.0; // 18 Kb blocks counted as paper's 2.1K 36Kb? use 36Kb tiles
pub const U250_URAM: f64 = 1_280.0;
pub const U250_DSP: f64 = 12_288.0;

/// The paper's prototype clock and memory system.
#[derive(Clone, Copy, Debug)]
pub struct FpgaModel {
    /// Accelerator clock (Hz). Paper: 140 MHz.
    pub clock_hz: f64,
    /// DDR channels per node. Paper: 4 x 16 GB DDR4.
    pub n_channels: usize,
    /// Bytes per channel per cycle through the AXI interface. Paper: 64.
    pub axi_bytes: usize,
    /// Board power under load (W) for the energy model (Table 5 regime).
    pub power_w: f64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        FpgaModel { clock_hz: 140e6, n_channels: 4, axi_bytes: 64, power_w: 45.0 }
    }
}

/// Per-query latency breakdown (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanLatency {
    pub lut_s: f64,
    pub scan_s: f64,
    pub kselect_drain_s: f64,
}

impl ScanLatency {
    pub fn total(&self) -> f64 {
        self.lut_s + self.scan_s + self.kselect_drain_s
    }
}

impl FpgaModel {
    /// Number of PQ decoding units instantiated for quantization width `m`
    /// (paper Sec 4.1: `channels * axi_width / m`).
    pub fn n_decoding_units(&self, m: usize) -> usize {
        (self.n_channels * self.axi_bytes / m).max(1)
    }

    /// PQ-code bytes consumed per second when all channels stream.
    pub fn scan_bandwidth(&self) -> f64 {
        self.clock_hz * (self.n_channels * self.axi_bytes) as f64
    }

    /// Latency for one query scanning `n_codes` vectors of `m`-byte codes
    /// over `nprobe` lists (paper's pipeline: LUT construction, streaming
    /// ADC decode, K-selection drain).
    pub fn query_latency(&self, n_codes: usize, m: usize, nprobe: usize, k: usize) -> ScanLatency {
        // LUT construction: 256 table entries per sub-space, all m
        // sub-spaces in parallel, one entry per cycle, one table per
        // probed list (per-list residual tables, Sec 4).
        let lut_cycles = 256.0 * nprobe as f64;
        // ADC scan: each decoding unit consumes one code (m bytes) per
        // cycle; all units run in parallel across channels, so the node
        // retires `units` codes per cycle when streaming.
        let units = self.n_decoding_units(m) as f64;
        let scan_cycles = n_codes as f64 / units;
        // K-selection is pipelined with the scan; only the final drain of
        // the hierarchical queue shows up as latency (L2 merge of
        // 2*units queues, two cycles per element).
        let drain_cycles = (2 * k) as f64 + 2.0 * units;
        ScanLatency {
            lut_s: lut_cycles / self.clock_hz,
            scan_s: scan_cycles / self.clock_hz,
            kselect_drain_s: drain_cycles / self.clock_hz,
        }
    }

    /// Batched query latency: queries stream back-to-back through the
    /// pipeline (LUT overlap with previous scan), so batch latency is one
    /// pipeline fill plus `b` scan phases.
    pub fn batch_latency(&self, b: usize, n_codes: usize, m: usize, nprobe: usize, k: usize) -> f64 {
        let one = self.query_latency(n_codes, m, nprobe, k);
        one.lut_s + one.kselect_drain_s + b as f64 * one.scan_s.max(one.lut_s)
    }

    /// Resource model for the full accelerator (Table 4 / Fig 8).
    ///
    /// Coefficients are calibrated against Table 4's reported fractions:
    /// the accelerator consumes ~20-28% LUTs with the dominant terms being
    /// the network stack + decoding units (per-unit cost scales with m via
    /// the m-way adder tree) and the K-selection queues (linear in total
    /// queue length, ~250 LUT/entry from Sec 4.2.1's "100-element queue ~
    /// 2.5% of U250 LUTs").
    pub fn resources(&self, m: usize, kcfg: &HierarchicalConfig) -> Resources {
        let units = self.n_decoding_units(m) as f64;
        // Fixed infrastructure: TCP/IP stack + DDR controllers + control.
        let base_lut = 220_000.0;
        let base_ff = 300_000.0;
        let base_bram = 220.0;
        // One decoding unit: m parallel lookups + adder tree + FIFO.
        let unit_lut = 900.0 + 260.0 * m as f64;
        let unit_ff = 1_200.0 + 320.0 * m as f64;
        let unit_bram = 1.0 + m as f64 / 4.0; // LUT table columns
        let unit_dsp = 2.0 * m as f64;
        // Priority queues: ~250 LUT / ~330 FF per entry (2.5% of U250 for
        // a 100-entry queue ~= 432 LUT/entry in their HLS; we fold the
        // compare-swap + control into 250 with FF separate).
        let q_entries = kcfg.resource_units() as f64;
        let q_lut = 250.0 * q_entries;
        let q_ff = 330.0 * q_entries;
        // LUT-construction unit: dsub-wide L2 distance pipeline.
        let lutc_dsp = 640.0;
        let lutc_lut = 30_000.0;
        Resources {
            lut: base_lut + units * unit_lut + q_lut + lutc_lut,
            ff: base_ff + units * unit_ff + q_ff + 40_000.0,
            bram: base_bram + units * unit_bram + 64.0,
            uram: 56.0, // metadata/address tables, constant
            dsp: 300.0 + units * unit_dsp + lutc_dsp,
        }
    }
}

/// Absolute resource counts; `fraction_of_u250` renders Table 4 rows.
#[derive(Clone, Copy, Debug)]
pub struct Resources {
    pub lut: f64,
    pub ff: f64,
    pub bram: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl Resources {
    pub fn fraction_of_u250(&self) -> [f64; 5] {
        [
            self.lut / U250_LUT,
            self.ff / U250_FF,
            self.bram / U250_BRAM,
            self.uram / U250_URAM,
            self.dsp / U250_DSP,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoding_unit_count_matches_paper_example() {
        // Paper Sec 4.1: m=32, 4 channels, 64-byte AXI => 8 units.
        let f = FpgaModel::default();
        assert_eq!(f.n_decoding_units(32), 8);
        assert_eq!(f.n_decoding_units(16), 16);
        assert_eq!(f.n_decoding_units(64), 4);
    }

    #[test]
    fn scan_bandwidth_is_35_8_gbs() {
        let f = FpgaModel::default();
        assert!((f.scan_bandwidth() / 35.84e9 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_scales_linearly_with_codes() {
        let f = FpgaModel::default();
        let a = f.query_latency(100_000, 16, 32, 100).scan_s;
        let b = f.query_latency(200_000, 16, 32, 100).scan_s;
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sift_scale_query_under_2ms() {
        // 1e9 vectors, nprobe 32/nlist 32768 -> ~1e6 codes scanned: the
        // paper's FPGA-GPU median for SIFT b=1 sits near 1-2 ms.
        let f = FpgaModel::default();
        let codes = (1e9 * 32.0 / 32768.0) as usize;
        let lat = f.query_latency(codes, 16, 32, 100).total();
        assert!(lat > 1e-4 && lat < 3e-3, "{lat}");
    }

    #[test]
    fn resources_within_u250_and_table4_band() {
        let f = FpgaModel::default();
        for &m in &[16usize, 32, 64] {
            let kcfg = HierarchicalConfig::approximate(100, 2 * f.n_decoding_units(m), 0.99);
            let r = f.resources(m, &kcfg);
            let frac = r.fraction_of_u250();
            // Table 4: LUT 23-28%, FF 15-19%, DSP 8-13%.
            assert!(frac[0] > 0.15 && frac[0] < 0.35, "m={m} LUT {}", frac[0]);
            assert!(frac[1] > 0.10 && frac[1] < 0.25, "m={m} FF {}", frac[1]);
            assert!(frac[4] > 0.05 && frac[4] < 0.20, "m={m} DSP {}", frac[4]);
        }
    }

    #[test]
    fn exact_queues_would_blow_lut_budget() {
        // Sec 4.2.1: full-length L1 queues are unaffordable. On our
        // 4-channel default with m=16 (16 units -> 32 L1 queues), exact
        // K=100 queues eat ~half the device on queues alone — the paper's
        // 32-unit configuration (64 queues) overflows it outright.
        let f = FpgaModel::default();
        let lanes = 2 * f.n_decoding_units(16);
        let exact = HierarchicalConfig::exact(100, lanes);
        let q_lut = 250.0 * exact.resource_units() as f64;
        assert!(q_lut > U250_LUT * 0.45, "{q_lut}");
        // Paper's example: 64 L1 queues.
        let paper = HierarchicalConfig::exact(100, 64);
        assert!(250.0 * paper.resource_units() as f64 > U250_LUT * 0.9);
        let approx = HierarchicalConfig::approximate(100, lanes, 0.99);
        let aq_lut = 250.0 * approx.resource_units() as f64;
        assert!(aq_lut < U250_LUT * 0.2, "{aq_lut}");
    }
}
