//! SLO-driven capacity planning: fit the per-stage service times observed
//! by the tracer (see [`crate::trace`]) into the LogGP scalability model
//! and answer "how many memory nodes / how much offered load for X QPS at
//! Y ms p99" — the planning loop the paper runs by hand around Fig 10.
//!
//! The model is deliberately simple: the coordinator's dispatch pipeline
//! serves one round at a time, so it is treated as an M/M/1 station whose
//! service time is the fitted critical path — LUT build + (scan, rescaled
//! inversely with node count from the fan-out it was observed at) + merge
//! + reply write + the LogGP broadcast/reduce round trip at the candidate
//! fan-out. Saturation ("the knee" of an open-loop latency-vs-load sweep)
//! is where offered load meets `1 / service_time`.

use crate::hwmodel::loggp::LogGp;
use crate::trace::{SpanKind, TraceAnalysis};

/// ln(100): multiplier from an M/M/1 mean sojourn time to its p99
/// (sojourn time is exponential with rate `mu - lambda`).
const P99_FACTOR: f64 = 4.605170185988091;

/// Observed mean per-stage service times of one serving configuration
/// (all seconds), as fitted from a trace snapshot.
#[derive(Clone, Copy, Debug)]
pub struct StageTimes {
    /// ADC table build (coordinator share + node shares).
    pub lut_s: f64,
    /// Per-query critical-path scan: the per-trace *max* across nodes,
    /// observed at `observed_nodes` fan-out.
    pub scan_s: f64,
    /// Top-K merge.
    pub merge_s: f64,
    /// Reply encode + socket write.
    pub reply_s: f64,
    /// Cache probe (0 when the retcache is off).
    pub cache_probe_s: f64,
    /// Speculation verify (0 when speculation is off).
    pub spec_verify_s: f64,
    /// Fan-out `scan_s` was measured at (scan work per node scales as
    /// `observed_nodes / nodes` under the list-major carve).
    pub observed_nodes: usize,
}

impl StageTimes {
    /// Fit stage times from an aggregated trace (mean critical-path
    /// contributions; `NodeScan` is already the per-trace max there).
    pub fn from_analysis(a: &TraceAnalysis, observed_nodes: usize) -> StageTimes {
        StageTimes {
            lut_s: a.stage_mean_s(SpanKind::LutBuild),
            scan_s: a.stage_mean_s(SpanKind::NodeScan),
            merge_s: a.stage_mean_s(SpanKind::Merge),
            reply_s: a.stage_mean_s(SpanKind::ReplyWrite),
            cache_probe_s: a.stage_mean_s(SpanKind::CacheProbe),
            spec_verify_s: a.stage_mean_s(SpanKind::SpecVerify),
            observed_nodes: observed_nodes.max(1),
        }
    }
}

/// Capacity planner over fitted stage times + the LogGP network model.
#[derive(Clone, Copy, Debug)]
pub struct CapacityPlanner {
    pub stages: StageTimes,
    pub net: LogGp,
    /// Broadcast payload per query (query vector + list ids).
    pub query_bytes: usize,
    /// Reduce payload per query (k results at 12 B each).
    pub result_bytes: usize,
}

impl CapacityPlanner {
    pub fn new(stages: StageTimes, query_bytes: usize, result_bytes: usize) -> CapacityPlanner {
        CapacityPlanner { stages, net: LogGp::default(), query_bytes, result_bytes }
    }

    /// Modeled per-query service time at `nodes` fan-out: the fitted
    /// critical path with the scan stage rescaled to the candidate node
    /// count and the LogGP round trip priced at that fan-out.
    pub fn service_s(&self, nodes: usize) -> f64 {
        let nodes = nodes.max(1);
        let s = &self.stages;
        let scan = s.scan_s * s.observed_nodes as f64 / nodes as f64;
        s.lut_s
            + s.cache_probe_s
            + s.spec_verify_s
            + scan
            + s.merge_s
            + s.reply_s
            + self.net.query_roundtrip(nodes, self.query_bytes, self.result_bytes)
    }

    /// Predicted saturation throughput (the open-loop knee): the single
    /// dispatch pipeline serves at most one service time per query.
    pub fn saturation_qps(&self, nodes: usize) -> f64 {
        1.0 / self.service_s(nodes)
    }

    /// Predicted p99 latency at `qps` offered load (M/M/1 sojourn p99 =
    /// `S / (1 - rho) * ln 100`). Infinite at or beyond saturation.
    pub fn p99_s(&self, nodes: usize, qps: f64) -> f64 {
        let s = self.service_s(nodes);
        let rho = qps * s;
        if rho >= 1.0 {
            return f64::INFINITY;
        }
        s / (1.0 - rho) * P99_FACTOR
    }

    /// Largest offered load meeting a p99 SLO at `nodes` fan-out
    /// (inverse of [`p99_s`](Self::p99_s); 0 when even an idle server
    /// misses the target).
    pub fn qps_for_p99(&self, nodes: usize, p99_target_s: f64) -> f64 {
        let s = self.service_s(nodes);
        if p99_target_s <= 0.0 {
            return 0.0;
        }
        let rho = 1.0 - s * P99_FACTOR / p99_target_s;
        (rho / s).max(0.0)
    }

    /// Smallest node count sustaining `qps` at the p99 SLO, or `None` if
    /// no fan-out up to 4096 gets there (the network term eventually
    /// dominates, so bigger is not always better).
    pub fn nodes_for(&self, qps: f64, p99_target_s: f64) -> Option<usize> {
        (1..=4096).find(|&n| self.p99_s(n, qps) <= p99_target_s)
    }

    /// Human-readable plan lines for a target SLO.
    pub fn render(&self, qps: f64, p99_target_s: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "planner: fitted service {:.3} ms at {} nodes (knee {:.0} q/s)\n",
            self.service_s(self.stages.observed_nodes) * 1e3,
            self.stages.observed_nodes,
            self.saturation_qps(self.stages.observed_nodes),
        ));
        match self.nodes_for(qps, p99_target_s) {
            Some(n) => out.push_str(&format!(
                "planner: {qps:.0} q/s at p99 <= {:.1} ms needs {n} node(s) \
                 (predicted p99 {:.2} ms, knee {:.0} q/s)\n",
                p99_target_s * 1e3,
                self.p99_s(n, qps) * 1e3,
                self.saturation_qps(n),
            )),
            None => out.push_str(&format!(
                "planner: no fan-out <= 4096 sustains {qps:.0} q/s at p99 <= {:.1} ms\n",
                p99_target_s * 1e3
            )),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> CapacityPlanner {
        CapacityPlanner::new(
            StageTimes {
                lut_s: 0.5e-3,
                scan_s: 4.0e-3,
                merge_s: 0.2e-3,
                reply_s: 0.3e-3,
                cache_probe_s: 0.0,
                spec_verify_s: 0.0,
                observed_nodes: 2,
            },
            4 * 128,
            12 * 10,
        )
    }

    #[test]
    fn more_nodes_cut_the_scan_term() {
        let p = fixture();
        assert!(p.service_s(4) < p.service_s(2));
        assert!(p.saturation_qps(4) > p.saturation_qps(2));
        // The knee is exactly the inverse of the service time.
        let s = p.service_s(3);
        assert!((p.saturation_qps(3) * s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p99_grows_toward_saturation_and_diverges_past_it() {
        let p = fixture();
        let knee = p.saturation_qps(2);
        let lo = p.p99_s(2, 0.2 * knee);
        let hi = p.p99_s(2, 0.9 * knee);
        assert!(lo.is_finite() && hi.is_finite());
        assert!(hi > 2.0 * lo, "{lo} vs {hi}");
        assert!(p.p99_s(2, knee).is_infinite());
        assert!(p.p99_s(2, 1.5 * knee).is_infinite());
        // Idle floor: p99 at ~zero load is the service time times ln 100.
        let idle = p.p99_s(2, 1e-9);
        assert!((idle - p.service_s(2) * P99_FACTOR).abs() / idle < 1e-3);
    }

    #[test]
    fn qps_for_p99_inverts_p99() {
        let p = fixture();
        let qps = 0.6 * p.saturation_qps(2);
        let target = p.p99_s(2, qps);
        let back = p.qps_for_p99(2, target);
        assert!((back - qps).abs() / qps < 1e-9, "{back} vs {qps}");
        // Unmeetable target: even idle misses it.
        assert_eq!(p.qps_for_p99(2, 1e-9), 0.0);
    }

    #[test]
    fn nodes_for_finds_the_smallest_feasible_fan_out() {
        let p = fixture();
        // A load the 2-node knee cannot carry but more nodes can.
        let qps = 1.2 * p.saturation_qps(2);
        let n = p.nodes_for(qps, 0.1).expect("feasible");
        assert!(n > 2, "needs more than the observed fan-out, got {n}");
        assert!(p.p99_s(n, qps) <= 0.1);
        if n > 1 {
            assert!(p.p99_s(n - 1, qps) > 0.1, "not minimal");
        }
        // An SLO below the irreducible (non-scan) critical path is
        // infeasible at any fan-out.
        assert_eq!(p.nodes_for(10.0, 1e-6), None);
        let text = p.render(qps, 0.1);
        assert!(text.contains("node(s)"), "{text}");
    }

    #[test]
    fn fits_from_a_trace_analysis() {
        use crate::trace::{analyze, SpanEvent};
        let ev = |kind, tag, dur_s| SpanEvent { trace_id: 1, kind, tag, t_us: 0, dur_s };
        let evs = vec![
            ev(SpanKind::QueueWait, 0, 0.001),
            ev(SpanKind::LutBuild, 0, 0.0005),
            ev(SpanKind::NodeScan, 0, 0.004),
            ev(SpanKind::NodeScan, 1, 0.003),
            ev(SpanKind::Merge, 0, 0.0002),
            ev(SpanKind::ReplyWrite, 0, 0.0003),
            ev(SpanKind::Total, 0, 0.006),
        ];
        let st = StageTimes::from_analysis(&analyze(&evs), 2);
        assert!((st.scan_s - 0.004).abs() < 1e-9, "max across nodes");
        assert!((st.lut_s - 0.0005).abs() < 1e-9);
        assert!((st.merge_s - 0.0002).abs() < 1e-9);
        assert_eq!(st.observed_nodes, 2);
        let p = CapacityPlanner::new(st, 512, 120);
        assert!(p.saturation_qps(2).is_finite());
        assert!(p.saturation_qps(2) > 0.0);
    }
}
