//! First-principles hardware performance/resource/energy models.
//!
//! The paper's testbed (Alveo U250 FPGAs, RTX 3090 GPUs, EPYC CPUs,
//! 100 Gbps network) does not exist here, so every accelerator latency in
//! the reports comes from these models — parameterized directly from the
//! paper's own numbers (Sec 4: 140 MHz, 4 channels x 64 B AXI; Sec 2.3:
//! ~1 GB/s/core CPU PQ scan, ~50% GPU bandwidth PQ scan; Sec 6.2: LogGP
//! with 10 us endpoint latency). The *measured* side (rust CPU scan,
//! PJRT-executed kernels) validates the shapes these models predict.
//!
//! This substitution is exactly the paper's own methodology for Fig 10,
//! which extrapolates beyond its two physical FPGAs with LogGP sampling.

pub mod capacity;
pub mod cpu;
pub mod energy;
pub mod fpga;
pub mod gpu;
pub mod loggp;
pub mod tpu;

pub use capacity::{CapacityPlanner, StageTimes};
pub use cpu::CpuModel;
pub use fpga::FpgaModel;
pub use gpu::GpuModel;
pub use loggp::LogGp;
