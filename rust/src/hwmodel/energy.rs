//! Energy-per-query model (paper Table 5): power x time for each engine
//! involved in a query. The paper measures CPU power via RAPL and GPU via
//! nvidia-smi; here both are modelled with load-power constants and the
//! latency models of this module's siblings.

use super::cpu::CpuModel;
use super::fpga::FpgaModel;
use super::gpu::GpuModel;
use crate::config::DatasetConfig;

/// Average energy per query (J) for the CPU-only baseline at batch `b`.
pub fn cpu_energy_per_query(
    cpu: &CpuModel,
    ds: &DatasetConfig,
    n_codes: usize,
    b: usize,
) -> f64 {
    let t_batch =
        cpu.query_latency(b, b * n_codes / b, ds.m, ds.dsub(), ds.nlist_paper, ds.nprobe);
    cpu.power_w * t_batch / b as f64
}

/// Average energy per query (J) for ChamVS (FPGA scan + GPU index scan).
pub fn chamvs_energy_per_query(
    fpga: &FpgaModel,
    gpu: &GpuModel,
    ds: &DatasetConfig,
    n_codes: usize,
    b: usize,
) -> f64 {
    let t_fpga = fpga.batch_latency(b, n_codes, ds.m, ds.nprobe, 100);
    let t_gpu = gpu.index_scan_latency(ds.nlist_paper, ds.d, b);
    (fpga.power_w * t_fpga + gpu.power_w * t_gpu) / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SIFT, SYN1024};

    fn paper_codes(ds: &DatasetConfig) -> usize {
        (ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64) as usize
    }

    #[test]
    fn chamvs_beats_cpu_by_5_to_30x() {
        // Table 5 band: 5.8-26.2x energy advantage.
        let (c, f, g) = (CpuModel::default(), FpgaModel::default(), GpuModel::default());
        for ds in [&SIFT, &SYN1024] {
            for b in [1usize, 4, 16] {
                let e_cpu = cpu_energy_per_query(&c, ds, paper_codes(ds), b);
                let e_cham = chamvs_energy_per_query(&f, &g, ds, paper_codes(ds), b);
                let ratio = e_cpu / e_cham;
                assert!(
                    ratio > 3.0 && ratio < 40.0,
                    "{} b={b}: ratio {ratio}",
                    ds.name
                );
            }
        }
    }

    #[test]
    fn sift_b1_energy_order_of_magnitude() {
        // Table 5: CPU SIFT b=1 = 950 mJ; model must land within ~3x.
        let c = CpuModel::default();
        let e = cpu_energy_per_query(&c, &SIFT, paper_codes(&SIFT), 1);
        assert!(e > 0.2 && e < 3.0, "{e} J");
    }

    #[test]
    fn batching_reduces_energy_per_query() {
        let (f, g) = (FpgaModel::default(), GpuModel::default());
        let e1 = chamvs_energy_per_query(&f, &g, &SIFT, paper_codes(&SIFT), 1);
        let e16 = chamvs_energy_per_query(&f, &g, &SIFT, paper_codes(&SIFT), 16);
        assert!(e16 < e1, "{e16} !< {e1}");
    }
}
