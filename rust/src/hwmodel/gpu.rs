//! GPU performance model: RTX 3090 for LLM inference and IVF index
//! scanning (paper Sec 6.1/6.2), plus the GPU PQ-scan inefficiency the
//! paper cites (Sec 2.3: ~50% of bandwidth even at large batch, after
//! multiple passes over intermediate results).

use crate::config::ModelConfig;

/// An LLM/IVF GPU model (RTX 3090 defaults).
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Device memory bandwidth (bytes/s). 3090: 936 GB/s GDDR6X.
    pub mem_bw: f64,
    /// Dense fp16/bf16 throughput (FLOP/s). 3090: ~71 TFLOPS tensor.
    pub peak_flops: f64,
    /// Effective fraction of peak FLOPs for batched transformer layers.
    pub flops_efficiency: f64,
    /// Effective fraction of bandwidth for PQ scanning (paper: ~0.5).
    pub pq_scan_bw_fraction: f64,
    /// Board power under load (W) for Table 5 / energy reports.
    pub power_w: f64,
    /// Kernel-launch + framework overhead per decode step (s).
    pub step_overhead: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            mem_bw: 936e9,
            peak_flops: 71e12,
            flops_efficiency: 0.45,
            pq_scan_bw_fraction: 0.5,
            power_w: 300.0,
            step_overhead: 200e-6,
        }
    }
}

impl GpuModel {
    /// One decode step for batch `b`: bandwidth-bound on parameters at
    /// small batch, compute-bound at large batch (2-byte weights).
    pub fn decode_step_latency(&self, model: &ModelConfig, b: usize) -> f64 {
        let param_bytes = 2.0 * model.param_count() as f64;
        let t_mem = param_bytes / self.mem_bw;
        let t_compute =
            b as f64 * model.decode_flops() / (self.peak_flops * self.flops_efficiency);
        self.step_overhead + t_mem.max(t_compute)
    }

    /// Encoder pass over retrieved chunks (EncDec models, compute-bound).
    pub fn encode_latency(&self, model: &ModelConfig, b: usize) -> f64 {
        if !model.is_encdec() {
            return 0.0;
        }
        let t = b as f64 * model.encode_flops()
            / (self.peak_flops * self.flops_efficiency);
        self.step_overhead + t
    }

    /// IVF index scan: query x nlist centroid distances + top-nprobe.
    /// Bandwidth-bound on reading the centroid matrix once per batch.
    pub fn index_scan_latency(&self, nlist: usize, d: usize, b: usize) -> f64 {
        let bytes = 4.0 * (nlist * d) as f64;
        let flops = 2.0 * (b * nlist * d) as f64;
        self.step_overhead / 4.0
            + (bytes / self.mem_bw).max(flops / (self.peak_flops * self.flops_efficiency))
    }

    /// PQ scan on GPU out of *host* memory over the interconnect (the
    /// CPU-GPU hybrid's fatal bottleneck, Sec 2.3) — not used by the
    /// paper's chosen baselines but exposed for ablations.
    pub fn pq_scan_host_latency(&self, n_codes: usize, m: usize, link_bw: f64) -> f64 {
        (n_codes * m) as f64 / link_bw
    }

    /// PQ scan on GPU out of device memory (Sec 2.3: ~50% of bandwidth).
    pub fn pq_scan_device_latency(&self, n_codes: usize, m: usize) -> f64 {
        (n_codes * m) as f64 / (self.mem_bw * self.pq_scan_bw_fraction)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DEC_L, DEC_S};

    #[test]
    fn small_batch_is_bandwidth_bound() {
        let g = GpuModel::default();
        let t1 = g.decode_step_latency(&DEC_S, 1);
        let t8 = g.decode_step_latency(&DEC_S, 8);
        // Same parameter traffic => nearly identical latency.
        assert!((t8 / t1 - 1.0).abs() < 0.2, "{t1} vs {t8}");
    }

    #[test]
    fn large_model_slower() {
        let g = GpuModel::default();
        assert!(
            g.decode_step_latency(&DEC_L, 1) > 5.0 * g.decode_step_latency(&DEC_S, 1)
        );
    }

    #[test]
    fn dec_s_tokens_per_second_plausible() {
        // 101M params * 2 B / 936 GB/s ~= 0.2 ms + overhead: hundreds to
        // thousands of tokens/s at b=1, as observed for small models.
        let g = GpuModel::default();
        let t = g.decode_step_latency(&DEC_S, 1);
        let tps = 1.0 / t;
        assert!(tps > 500.0 && tps < 5000.0, "{tps}");
    }

    #[test]
    fn index_scan_fast_but_not_free() {
        let g = GpuModel::default();
        let t = g.index_scan_latency(32_768, 512, 1);
        assert!(t > 1e-5 && t < 1e-3, "{t}");
    }

    #[test]
    fn compute_bound_at_huge_batch() {
        let g = GpuModel::default();
        let t256 = g.decode_step_latency(&DEC_S, 256);
        let t1 = g.decode_step_latency(&DEC_S, 1);
        assert!(t256 > 1.5 * t1, "{t256} vs {t1}");
    }
}
