//! TPU roofline estimates for the L1 Pallas kernels (DESIGN.md Sec 8).
//!
//! interpret=True gives CPU-numpy timings only, so real-TPU performance of
//! the kernels is *estimated* from their BlockSpec structure: VMEM bytes
//! per tile, HBM traffic, and MXU/VPU FLOPs. These numbers cross-check the
//! `cost` dicts aot.py embeds in artifacts/manifest.json.

/// TPU v4-class machine constants (one core).
pub const HBM_BW: f64 = 1.2e12; // bytes/s
pub const PEAK_BF16: f64 = 275e12; // FLOP/s
pub const VMEM_BYTES: f64 = 16.0 * 1024.0 * 1024.0;

/// Roofline estimate for one kernel invocation.
#[derive(Clone, Copy, Debug)]
pub struct KernelEstimate {
    pub flops: f64,
    pub hbm_bytes: f64,
    pub vmem_bytes_per_tile: f64,
    /// Fraction of MXU MACs doing useful work (1.0 = dense-efficient).
    pub mxu_utilization: f64,
}

impl KernelEstimate {
    /// max(compute, memory) latency in seconds.
    pub fn latency_s(&self) -> f64 {
        (self.flops / PEAK_BF16).max(self.hbm_bytes / HBM_BW)
    }

    /// Arithmetic intensity (FLOP/byte); the v4 ridge point is ~230.
    pub fn intensity(&self) -> f64 {
        self.flops / self.hbm_bytes.max(1.0)
    }

    pub fn fits_vmem(&self) -> bool {
        self.vmem_bytes_per_tile <= VMEM_BYTES
    }
}

/// The kernel's m-dependent tile rule (mirrors `pq_scan.n_tile`): keeps
/// the one-hot expansion at ~8 MiB of VMEM regardless of PQ width.
pub fn adc_n_tile(m: usize) -> usize {
    (8192 / m).max(128)
}

/// One-hot-MXU ADC scan over `n` codes of width `m` (pq_scan.py).
pub fn adc_scan_estimate(n: usize, m: usize, n_tile: usize) -> KernelEstimate {
    let flops = 2.0 * (n * m * 256) as f64; // dense contraction
    let useful = 2.0 * (n * m) as f64; // lookups + adds actually required
    KernelEstimate {
        flops,
        hbm_bytes: (n * m * 4) as f64, // i32 codes stream once
        vmem_bytes_per_tile: 4.0 * (n_tile * m * 256 + n_tile * m + m * 256) as f64,
        mxu_utilization: useful / flops,
    }
}

/// LUT construction (pq_lut.py): VPU broadcast-square-reduce.
pub fn lut_estimate(m: usize, dsub: usize) -> KernelEstimate {
    KernelEstimate {
        flops: 3.0 * (m * 256 * dsub) as f64,
        hbm_bytes: 4.0 * (m * 256 * dsub + m * dsub + m * 256) as f64,
        vmem_bytes_per_tile: 4.0 * (8 * 256 * dsub) as f64,
        mxu_utilization: 0.0, // pure VPU
    }
}

/// IVF centroid scan (ivf_scan.py): dense (b, d) x (d, nlist) matmul.
pub fn ivf_scan_estimate(b: usize, nlist: usize, d: usize, c_tile: usize) -> KernelEstimate {
    KernelEstimate {
        flops: 2.0 * (b * nlist * d) as f64,
        hbm_bytes: 4.0 * (nlist * d + b * d + b * nlist) as f64,
        vmem_bytes_per_tile: 4.0 * (b * d + c_tile * d + b * c_tile) as f64,
        mxu_utilization: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_tiles_fit_vmem() {
        for &m in &[16usize, 32, 64] {
            let e = adc_scan_estimate(32_768, m, adc_n_tile(m));
            assert!(e.fits_vmem(), "m={m}: {} bytes", e.vmem_bytes_per_tile);
        }
    }

    #[test]
    fn fixed_tile_would_overflow_vmem() {
        // The bug the tile rule fixes: a flat 512-tile at m=32 needs
        // ~16.8 MB of VMEM for the one-hot expansion alone.
        let e = adc_scan_estimate(32_768, 32, 512);
        assert!(!e.fits_vmem());
    }

    #[test]
    fn adc_utilization_is_1_over_256() {
        let e = adc_scan_estimate(1000, 32, 512);
        assert!((e.mxu_utilization - 1.0 / 256.0).abs() < 1e-9);
    }

    #[test]
    fn ivf_scan_bandwidth_bound_at_b1() {
        let e = ivf_scan_estimate(1, 32_768, 512, 1024);
        // intensity ~ 2 flops/4 bytes per centroid element: << ridge.
        assert!(e.intensity() < 2.0);
        assert!(e.latency_s() > e.flops / PEAK_BF16);
    }

    #[test]
    fn adc_scan_faster_than_fpga_at_paper_scale() {
        // Sanity: a TPU running the one-hot ADC at 1/256 utilization still
        // beats the 35.8 GB/s FPGA stream for m=16 paper-scale scans,
        // because the code stream is only 4 B/code.
        let n = 1_000_000;
        let e = adc_scan_estimate(n, 16, 512);
        let fpga_s = (n * 16) as f64 / 35.84e9;
        assert!(e.latency_s() < fpga_s * 4.0, "{} vs {}", e.latency_s(), fpga_s);
    }
}
