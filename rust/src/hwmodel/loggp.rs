//! LogGP network model (paper Sec 6.2 "Scalability"; Culler et al. /
//! Alexandrov et al.). The paper extrapolates multi-node latency with a
//! tree-topology broadcast/reduce, 10 us endpoint latency and 100 Gbps
//! links; Fig 10 is regenerated from the same model here.

/// LogGP parameters.
#[derive(Clone, Copy, Debug)]
pub struct LogGp {
    /// End-to-end latency between two endpoints (s). Paper: 10 us.
    pub latency_s: f64,
    /// Per-message CPU overhead (s).
    pub overhead_s: f64,
    /// Gap per byte for long messages = 1 / bandwidth (s/B). 100 Gbps.
    pub gap_per_byte: f64,
}

impl Default for LogGp {
    fn default() -> Self {
        LogGp {
            latency_s: 10e-6,
            overhead_s: 1e-6,
            gap_per_byte: 8.0 / 100e9,
        }
    }
}

impl LogGp {
    /// Point-to-point time for a `bytes`-long message.
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.latency_s + 2.0 * self.overhead_s + bytes as f64 * self.gap_per_byte
    }

    /// Broadcast to `n` nodes over a binary tree: ceil(log2(n)) rounds.
    pub fn broadcast(&self, n: usize, bytes: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        tree_rounds(n) as f64 * self.p2p(bytes)
    }

    /// Reduce from `n` nodes (same tree structure, same cost shape).
    pub fn reduce(&self, n: usize, bytes: usize) -> f64 {
        self.broadcast(n, bytes)
    }

    /// Full ChamVS round trip: broadcast query to `n` memory nodes,
    /// reduce per-node top-K results back (paper's Fig 10 setup).
    pub fn query_roundtrip(&self, n: usize, query_bytes: usize, result_bytes: usize) -> f64 {
        if n <= 1 {
            // Single node still crosses the network once each way.
            return self.p2p(query_bytes) + self.p2p(result_bytes);
        }
        self.broadcast(n, query_bytes) + self.reduce(n, result_bytes)
    }
}

/// Rounds in a binary broadcast tree.
fn tree_rounds(n: usize) -> u32 {
    (usize::BITS - (n - 1).leading_zeros()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_small_message_is_latency_dominated() {
        let g = LogGp::default();
        let t = g.p2p(256);
        assert!(t > 10e-6 && t < 15e-6, "{t}");
    }

    #[test]
    fn tree_rounds_log2() {
        assert_eq!(tree_rounds(2), 1);
        assert_eq!(tree_rounds(4), 2);
        assert_eq!(tree_rounds(8), 3);
        assert_eq!(tree_rounds(5), 3);
        assert_eq!(tree_rounds(16), 4);
    }

    #[test]
    fn broadcast_grows_logarithmically() {
        let g = LogGp::default();
        let t4 = g.broadcast(4, 1024);
        let t16 = g.broadcast(16, 1024);
        assert!((t16 / t4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn roundtrip_small_vs_query_time() {
        // Paper: network latency negligible vs query latency (ms-scale);
        // the 16-node roundtrip must stay below 200 us.
        let g = LogGp::default();
        let t = g.query_roundtrip(16, 2048 + 32 * 4, 100 * 12);
        assert!(t < 200e-6, "{t}");
    }

    #[test]
    fn bandwidth_term_dominates_large_messages() {
        let g = LogGp::default();
        let t = g.p2p(100_000_000);
        assert!((t - 0.008).abs() / 0.008 < 0.01, "{t}");
    }
}
