//! The retrieval half of a RALM step: IVF probe (ChamVS.idx) + broadcast
//! scan over memory nodes (ChamVS.mem) + vector-ID -> token conversion
//! (paper Sec 3 workflow steps 1-9).

use std::time::Instant;

use anyhow::Result;

use crate::chamvs::dispatcher::Dispatcher;
use crate::config::DatasetConfig;
use crate::data::corpus::Corpus;
use crate::hwmodel::gpu::GpuModel;
use crate::ivf::index::IvfPqIndex;

/// One retrieval's outcome.
#[derive(Clone, Debug)]
pub struct RetrievalResult {
    pub ids: Vec<u64>,
    pub dists: Vec<f32>,
    /// Modeled paper-scale retrieval latency: GPU index scan + FPGA scan
    /// + network round trip.
    pub modeled_s: f64,
    /// Host wall-clock actually spent.
    pub measured_s: f64,
}

/// Retrieval engine: index + dispatcher + token store.
pub struct Retriever {
    pub ds: &'static DatasetConfig,
    pub index: IvfPqIndex,
    pub dispatcher: Dispatcher,
    pub corpus: Corpus,
    pub gpu: GpuModel,
    /// If true, stage latencies are modeled at paper scale (1e9 vectors).
    pub paper_scale: bool,
}

impl Retriever {
    pub fn new(
        ds: &'static DatasetConfig,
        index: IvfPqIndex,
        dispatcher: Dispatcher,
        corpus: Corpus,
    ) -> Retriever {
        Retriever {
            ds,
            index,
            dispatcher,
            corpus,
            gpu: GpuModel::default(),
            paper_scale: true,
        }
    }

    /// Database vector dimensionality (query dimension).
    pub fn dim(&self) -> usize {
        self.index.d
    }

    pub fn k(&self) -> usize {
        self.dispatcher.k
    }

    /// Full retrieval for one query vector.
    pub fn retrieve(&mut self, query: &[f32]) -> Result<RetrievalResult> {
        let t0 = Instant::now();
        let nprobe = self.ds.nprobe;
        // Step 2: IVF index scan (GPU-colocated in the paper).
        let lists = self.index.probe(query, nprobe);
        // Steps 4-8: broadcast to memory nodes, scan, aggregate.
        let r = self
            .dispatcher
            .search(query, &self.index.pq.centroids, &lists, nprobe)?;

        let nlist = if self.paper_scale {
            self.ds.nlist_paper
        } else {
            self.index.nlist
        };
        let idx_s = self.gpu.index_scan_latency(nlist, self.ds.d, 1);
        let scan_s = if self.paper_scale {
            // Rescale the FPGA stage to paper-scale codes per node.
            let paper_codes = self.ds.n_paper as f64 * nprobe as f64
                / self.ds.nlist_paper as f64;
            let per_node = (paper_codes / self.dispatcher.nodes.len() as f64) as usize;
            self.dispatcher.nodes[0]
                .fpga
                .query_latency(per_node, self.ds.m, nprobe, self.dispatcher.k)
                .total()
        } else {
            r.accel_s
        };
        let modeled_s = idx_s + scan_s + r.network_s;
        Ok(RetrievalResult {
            ids: r.topk.iter().map(|&(_, i)| i).collect(),
            dists: r.topk.iter().map(|&(d, _)| d).collect(),
            modeled_s,
            measured_s: t0.elapsed().as_secs_f64(),
        })
    }

    /// Step 9: convert neighbor ids to next-tokens (decoder-only payload).
    pub fn gather_next_tokens(&self, ids: &[u64]) -> Vec<u32> {
        self.corpus.gather_next_tokens(ids)
    }

    /// Convert neighbor ids to concatenated chunks (EncDec payload).
    pub fn gather_chunks(&self, ids: &[u64]) -> Vec<u32> {
        self.corpus.gather_chunks(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::{MemoryNode, ScanEngine};
    use crate::config::SIFT;
    use crate::data::synthetic::SyntheticDataset;
    use crate::ivf::shard::Shard;

    fn toy_retriever(n_nodes: usize) -> Retriever {
        let ds = SyntheticDataset::generate_sized(&SIFT, 2000, 4, 1);
        let index = IvfPqIndex::build(&ds.data, ds.n, ds.d, SIFT.m, 32, 2);
        let nodes = (0..n_nodes)
            .map(|i| {
                MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, 10)
            })
            .collect();
        let dispatcher = Dispatcher::new(nodes, 10);
        let corpus = Corpus::generate(2000, 2048, 8, 3);
        Retriever::new(&SIFT, index, dispatcher, corpus)
    }

    #[test]
    fn retrieve_returns_k_results() {
        let mut r = toy_retriever(2);
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let out = r.retrieve(ds.query(0)).unwrap();
        assert_eq!(out.ids.len(), 10);
        assert_eq!(out.dists.len(), 10);
        assert!(out.dists.windows(2).all(|w| w[0] <= w[1]));
        assert!(out.modeled_s > 0.0);
    }

    #[test]
    fn tokens_follow_ids() {
        let r = toy_retriever(1);
        let toks = r.gather_next_tokens(&[0, 1, 2]);
        assert_eq!(toks.len(), 3);
        let chunks = r.gather_chunks(&[0, 1]);
        assert_eq!(chunks.len(), 16);
    }

    #[test]
    fn self_query_finds_itself() {
        // Querying with database vector 0 must return id 0 first (PQ
        // distance to itself is minimal among clustered data).
        let mut r = toy_retriever(1);
        let q: Vec<f32> = r.index.pq.centroids[..0].to_vec(); // placeholder
        drop(q);
        let ds = SyntheticDataset::generate_sized(&SIFT, 2000, 4, 1);
        let out = r.retrieve(ds.vector(0)).unwrap();
        assert!(
            out.ids.contains(&0),
            "self id missing from {:?}",
            &out.ids[..5.min(out.ids.len())]
        );
    }
}
