//! The retrieval half of a RALM step: IVF probe (ChamVS.idx) + broadcast
//! scan over memory nodes (ChamVS.mem) + vector-ID -> token conversion
//! (paper Sec 3 workflow steps 1-9).

use std::time::Instant;

use anyhow::Result;

use crate::chamvs::backend::ScanBackend;
use crate::chamvs::dispatcher::{BatchQuery, Dispatcher, SearchResult};
use crate::cluster::engine::RoundOptions;
use crate::config::DatasetConfig;
use crate::data::corpus::Corpus;
use crate::hwmodel::gpu::GpuModel;
use crate::ivf::index::IvfPqIndex;
use crate::retcache::{
    charged_latency, CacheConfig, CachedEntry, RetrievalCache, RetrievalSource,
    RetrievalStats, SlicedCache, SpecConfig, SpecSlots, SpecVerdict,
};
use crate::trace::{SpanKind, Tracer};
use crate::util::metrics::Metrics;

/// One retrieval's outcome.
#[derive(Clone, Debug)]
pub struct RetrievalResult {
    pub ids: Vec<u64>,
    pub dists: Vec<f32>,
    /// Modeled paper-scale retrieval latency: GPU index scan + FPGA scan
    /// + network round trip.
    pub modeled_s: f64,
    /// Host wall-clock actually spent.
    pub measured_s: f64,
    /// Shards that contributed / total shards of the round (`0/0` = flat
    /// dispatch or complete by construction) — see
    /// [`SearchResult::coverage`].
    pub shards_answered: u32,
    pub n_shards: u32,
}

impl RetrievalResult {
    /// Fraction of shards that contributed (`1.0` = complete).
    pub fn coverage(&self) -> f64 {
        if self.n_shards == 0 {
            1.0
        } else {
            self.shards_answered as f64 / self.n_shards as f64
        }
    }

    /// Whether some shard's results are missing.
    pub fn is_partial(&self) -> bool {
        self.n_shards != 0 && self.shards_answered < self.n_shards
    }
}

/// A retrieval served through the cache-aware path: the result plus where
/// it came from. `result.modeled_s` is always the *full* synchronous
/// round-trip latency; how much of it a serving step actually pays is
/// decided by [`crate::retcache::charged_latency`].
#[derive(Clone, Debug)]
pub struct CachedRetrieval {
    pub result: RetrievalResult,
    pub source: RetrievalSource,
}

/// Retrieval engine: index + dispatcher + token store, optionally fronted
/// by the `retcache` subsystem (retrieval cache + speculative prefetch).
pub struct Retriever {
    pub ds: &'static DatasetConfig,
    pub index: IvfPqIndex,
    pub dispatcher: Dispatcher,
    pub corpus: Corpus,
    pub gpu: GpuModel,
    /// If true, stage latencies are modeled at paper scale (1e9 vectors).
    pub paper_scale: bool,
    /// Retrieval cache (None = seed synchronous behaviour).
    pub cache: Option<RetrievalCache>,
    /// Per-tenant slices of one retrieval-cache byte budget; requests
    /// carrying a tenant id (`retrieve_cached_tenant_traced`) use their
    /// tenant's slice instead of the shared `cache`, so one tenant's
    /// working set can't evict another's.
    pub tenant_cache: Option<SlicedCache>,
    /// Per-GPU speculative prefetch lanes (None = no speculation). Each
    /// request source (GPU id) owns an independent slot; see
    /// [`retrieve_cached_from`](Self::retrieve_cached_from).
    pub spec: Option<SpecSlots>,
    /// Counters over the cache-aware path.
    pub rstats: RetrievalStats,
}

impl Retriever {
    pub fn new(
        ds: &'static DatasetConfig,
        index: IvfPqIndex,
        dispatcher: Dispatcher,
        corpus: Corpus,
    ) -> Retriever {
        Retriever {
            ds,
            index,
            dispatcher,
            corpus,
            gpu: GpuModel::default(),
            paper_scale: true,
            cache: None,
            tenant_cache: None,
            spec: None,
            rstats: RetrievalStats::default(),
        }
    }

    /// Enable (or reconfigure — the cache restarts cold) the retrieval
    /// cache.
    pub fn enable_cache(&mut self, cfg: CacheConfig) {
        self.cache = Some(RetrievalCache::new(cfg));
    }

    /// Enable per-tenant slicing of the retrieval-cache byte budget:
    /// `cfg.capacity_bytes` is the *total*, re-divided evenly as tenants
    /// appear. Requests carrying a tenant id
    /// ([`retrieve_cached_tenant_traced`](Self::retrieve_cached_tenant_traced))
    /// then probe/refill their own slice; tenant-less requests keep using
    /// the shared cache, if any.
    pub fn enable_tenant_cache(&mut self, cfg: CacheConfig) {
        self.tenant_cache = Some(SlicedCache::new(cfg));
    }

    /// Enable (or reconfigure) speculative prefetching.
    pub fn enable_speculation(&mut self, cfg: SpecConfig) {
        self.cancel_speculation();
        self.spec = Some(SpecSlots::new(cfg));
    }

    /// Drop every slot's in-flight speculative query (server teardown,
    /// reconfiguration) without counting them as mis-speculations.
    pub fn cancel_speculation(&mut self) {
        if let Some(s) = self.spec.as_mut() {
            for t in s.take_all_in_flight() {
                self.dispatcher.cancel(t);
            }
        }
    }

    /// Drop one slot's in-flight speculative query (sequence boundary on
    /// that GPU stream) without touching the other slots' lanes.
    pub fn cancel_slot_speculation(&mut self, slot: usize) {
        if let Some(s) = self.spec.as_mut() {
            if let Some(t) = s.take_in_flight(slot) {
                self.dispatcher.cancel(t);
            }
        }
    }

    /// Install a span sink: retrieval stages (`cache_probe`,
    /// `spec_verify`, and the dispatcher's `lut_build`/`node_scan`/
    /// `merge`) are recorded for requests carrying a nonzero trace id.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.dispatcher.tracer = tracer;
    }

    /// The installed span sink (off by default).
    pub fn tracer(&self) -> &Tracer {
        &self.dispatcher.tracer
    }

    /// Whether [`retrieve_cached`](Self::retrieve_cached) does anything
    /// beyond plain [`retrieve`](Self::retrieve).
    pub fn retcache_enabled(&self) -> bool {
        self.cache.is_some() || self.tenant_cache.is_some() || self.spec.is_some()
    }

    /// Reset the retcache counters (benches reuse one retriever).
    pub fn reset_retcache_stats(&mut self) {
        self.rstats = RetrievalStats::default();
    }

    /// Human-readable retcache block for the serve reports.
    pub fn cache_report(&self) -> String {
        self.rstats.render(self.cache.as_ref(), self.spec.as_ref())
    }

    /// Export the retcache counters into a metrics registry.
    pub fn export_metrics(&self, m: &Metrics) {
        self.rstats.export(m, self.cache.as_ref(), self.spec.as_ref());
    }

    /// Mirror the retcache counters into the live telemetry registry as
    /// absolute gauges (repeat-safe; called after every served batch).
    /// No-op when the retcache path is disabled.
    pub fn export_telemetry(&self, reg: &crate::telemetry::Registry) {
        if self.retcache_enabled() {
            self.rstats
                .export_telemetry(reg, self.cache.as_ref(), self.spec.as_ref());
        }
    }

    /// The decode window a speculative prefetch may overlap with:
    /// `interval * speculation_depth` decode steps.
    pub fn overlap_window_s(&self, decode_s: f64, interval: usize) -> f64 {
        let depth = self.spec.as_ref().map(|s| s.cfg.depth.max(1)).unwrap_or(1);
        (interval.max(1) * depth) as f64 * decode_s
    }

    /// Modeled latency a serving step pays for a cached retrieval
    /// (see [`crate::retcache::charged_latency`]), accruing the
    /// saved-latency stat. The single accounting point shared by the
    /// generator, the batch engine, and the worker-free serve model.
    pub fn charge_retrieval(
        &mut self,
        cr: &CachedRetrieval,
        decode_s: f64,
        interval: usize,
    ) -> f64 {
        let overlap = self.overlap_window_s(decode_s, interval);
        let charged = charged_latency(cr.source, cr.result.modeled_s, overlap);
        self.rstats.saved_modeled_s += (cr.result.modeled_s - charged).max(0.0);
        charged
    }

    /// Database vector dimensionality (query dimension).
    pub fn dim(&self) -> usize {
        self.index.d
    }

    pub fn k(&self) -> usize {
        self.dispatcher.k
    }

    /// Modeled paper-scale latency of one dispatcher search: GPU index
    /// scan + FPGA scan (rescaled to paper-scale codes per node when
    /// `paper_scale`) + network round trip.
    fn model_search_latency(&self, r: &SearchResult, nprobe: usize) -> f64 {
        let nlist = if self.paper_scale {
            self.ds.nlist_paper
        } else {
            self.index.nlist
        };
        let idx_s = self.gpu.index_scan_latency(nlist, self.ds.d, 1);
        let scan_s = if self.paper_scale {
            // Rescale the FPGA stage to paper-scale codes per node.
            let paper_codes =
                self.ds.n_paper as f64 * nprobe as f64 / self.ds.nlist_paper as f64;
            let per_node =
                (paper_codes / self.dispatcher.fan_out().max(1) as f64) as usize;
            self.dispatcher
                .fpga()
                .query_latency(per_node, self.ds.m, nprobe, self.dispatcher.k)
                .total()
        } else {
            r.accel_s
        };
        idx_s + scan_s + r.network_s
    }

    fn search_to_result(&self, r: SearchResult, nprobe: usize, t0: Instant) -> RetrievalResult {
        let measured_s = t0.elapsed().as_secs_f64();
        self.result_with_measured(r, nprobe, measured_s)
    }

    /// The single `SearchResult` -> `RetrievalResult` mapping (ids/dists
    /// extraction + paper-scale latency model); `measured_s` is supplied
    /// by the caller because its honest value differs by path (end-to-end
    /// elapsed for blocking retrievals, per-job parallel wall for batched
    /// rounds).
    fn result_with_measured(
        &self,
        r: SearchResult,
        nprobe: usize,
        measured_s: f64,
    ) -> RetrievalResult {
        let modeled_s = self.model_search_latency(&r, nprobe);
        RetrievalResult {
            ids: r.topk.iter().map(|&(_, i)| i).collect(),
            dists: r.topk.iter().map(|&(d, _)| d).collect(),
            modeled_s,
            measured_s,
            shards_answered: r.shards_answered,
            n_shards: r.n_shards,
        }
    }

    /// Full retrieval for one query vector.
    pub fn retrieve(&mut self, query: &[f32]) -> Result<RetrievalResult> {
        self.retrieve_traced(query, 0)
    }

    /// [`retrieve`](Self::retrieve) carrying an end-to-end trace id (0 =
    /// untraced): dispatcher-stage spans land under `trace_id` when a
    /// tracer is installed.
    pub fn retrieve_traced(
        &mut self,
        query: &[f32],
        trace_id: u64,
    ) -> Result<RetrievalResult> {
        self.retrieve_with(query, trace_id, &RoundOptions::default())
    }

    /// [`retrieve_traced`](Self::retrieve_traced) with per-round options:
    /// the remaining end-to-end deadline budget and the degraded-mode
    /// policy, enforced by the cluster engine (see
    /// [`Dispatcher::search_opts`]).
    pub fn retrieve_with(
        &mut self,
        query: &[f32],
        trace_id: u64,
        opts: &RoundOptions,
    ) -> Result<RetrievalResult> {
        let t0 = Instant::now();
        let nprobe = self.ds.nprobe;
        // Step 2: IVF index scan (GPU-colocated in the paper).
        let lists = self.index.probe(query, nprobe);
        // Steps 4-8: broadcast to memory nodes, scan, aggregate.
        let r = self.dispatcher.search_opts(
            query,
            &self.index.pq.centroids,
            &lists,
            nprobe,
            trace_id,
            opts,
        )?;
        Ok(self.search_to_result(r, nprobe, t0))
    }

    /// Batched retrieval: probe every query, then run ONE parallel
    /// dispatch round through the memory nodes' per-node work queues
    /// ([`Dispatcher::search_batch`]) — the RAGO-style multi-query lever.
    /// Per-query results and modeled latencies are identical to
    /// sequential [`retrieve`](Self::retrieve) calls; the fan-out round
    /// is paid once instead of B times, and any queued speculative
    /// tickets execute in the same round.
    pub fn retrieve_many(&mut self, queries: &[&[f32]]) -> Result<Vec<RetrievalResult>> {
        self.retrieve_many_traced(queries, &[])
    }

    /// [`retrieve_many`](Self::retrieve_many) with per-query trace ids
    /// (shorter-than-batch or empty `trace_ids` leaves the tail untraced).
    pub fn retrieve_many_traced(
        &mut self,
        queries: &[&[f32]],
        trace_ids: &[u64],
    ) -> Result<Vec<RetrievalResult>> {
        self.retrieve_many_with(queries, trace_ids, &RoundOptions::default())
    }

    /// [`retrieve_many_traced`](Self::retrieve_many_traced) with
    /// per-round options; the shared round's deadline should be the
    /// tightest of the batched queries' budgets.
    pub fn retrieve_many_with(
        &mut self,
        queries: &[&[f32]],
        trace_ids: &[u64],
        opts: &RoundOptions,
    ) -> Result<Vec<RetrievalResult>> {
        let nprobe = self.ds.nprobe;
        let lists: Vec<Vec<u32>> =
            queries.iter().map(|q| self.index.probe(q, nprobe)).collect();
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(&lists)
            .enumerate()
            .map(|(i, (q, l))| BatchQuery {
                query: q,
                lists: l,
                trace_id: trace_ids.get(i).copied().unwrap_or(0),
            })
            .collect();
        let rs = self
            .dispatcher
            .search_batch_opts(&batch, &self.index.pq.centroids, nprobe, opts)?;
        // Per-query measured time is the job's own parallel wall — the
        // round's elapsed time would absorb piggybacked speculative scans
        // from other slots, which the dispatcher's accounting contract
        // keeps out of blocking retrieval numbers.
        Ok(rs
            .into_iter()
            .map(|r| {
                let measured_s = r.measured_wall_s;
                self.result_with_measured(r, nprobe, measured_s)
            })
            .collect())
    }

    /// Cache-aware retrieval: serve from the retrieval cache, else from a
    /// verified speculative prefetch, else run the full round trip — and
    /// in the latter cases refill the cache and launch the next
    /// speculative query on the dispatcher.
    ///
    /// Results are identical to [`retrieve`](Self::retrieve) with exact
    /// keys and zero speculation tolerance; a quantized key or nonzero
    /// tolerance may serve a near-duplicate query's neighbors — the
    /// knobs' documented fidelity/latency trade-off.
    pub fn retrieve_cached(&mut self, query: &[f32]) -> Result<CachedRetrieval> {
        self.retrieve_cached_from(0, query)
    }

    /// [`retrieve_cached`](Self::retrieve_cached) on an explicit
    /// speculation slot: each GPU source (request stream) owns one slot,
    /// so its prefetch lane is verified, consumed and cancelled in
    /// isolation — interleaved streams never invalidate each other's
    /// in-flight speculative queries. The retrieval cache itself is
    /// shared across slots (results are source-independent).
    pub fn retrieve_cached_from(
        &mut self,
        slot: usize,
        query: &[f32],
    ) -> Result<CachedRetrieval> {
        self.retrieve_cached_from_traced(slot, query, 0)
    }

    /// [`retrieve_cached_from`](Self::retrieve_cached_from) carrying an
    /// end-to-end trace id: records `cache_probe` and `spec_verify` spans
    /// (tag = hit flag) on top of the dispatcher's stage spans.
    pub fn retrieve_cached_from_traced(
        &mut self,
        slot: usize,
        query: &[f32],
        trace_id: u64,
    ) -> Result<CachedRetrieval> {
        self.retrieve_cached_tenant_traced(slot, None, query, trace_id)
    }

    /// [`retrieve_cached_from_traced`](Self::retrieve_cached_from_traced)
    /// on behalf of a tenant: when tenant cache slicing is enabled, the
    /// probe and refill go through `tenant`'s slice of the shared byte
    /// budget instead of the global cache. `None` (or slicing disabled)
    /// falls back to the shared cache, preserving the old behaviour.
    pub fn retrieve_cached_tenant_traced(
        &mut self,
        slot: usize,
        tenant: Option<u32>,
        query: &[f32],
        trace_id: u64,
    ) -> Result<CachedRetrieval> {
        self.retrieve_cached_opts(slot, tenant, query, trace_id, &RoundOptions::default())
    }

    /// [`retrieve_cached_tenant_traced`](Self::retrieve_cached_tenant_traced)
    /// with per-round options: the deadline budget and degraded-mode
    /// policy apply to the full-round-trip fallback (cache and
    /// speculation hits are always complete results and pay no round).
    pub fn retrieve_cached_opts(
        &mut self,
        slot: usize,
        tenant: Option<u32>,
        query: &[f32],
        trace_id: u64,
        opts: &RoundOptions,
    ) -> Result<CachedRetrieval> {
        let t0 = Instant::now();
        // 1) Retrieval cache.
        let mut hit: Option<RetrievalResult> = None;
        if let Some(cache) =
            active_cache(&mut self.cache, &mut self.tenant_cache, tenant)
        {
            let t_probe = Instant::now();
            let entry = cache.get(query);
            if trace_id != 0 {
                self.dispatcher.tracer.record(
                    trace_id,
                    SpanKind::CacheProbe,
                    u32::from(entry.is_some()),
                    t_probe.elapsed().as_secs_f64(),
                );
            }
            if let Some(e) = entry {
                hit = Some(RetrievalResult {
                    ids: e.ids.clone(),
                    dists: e.dists.clone(),
                    modeled_s: e.modeled_s,
                    measured_s: t0.elapsed().as_secs_f64(),
                    // Only complete results are inserted, so a hit is
                    // always full-coverage.
                    shards_answered: 0,
                    n_shards: 0,
                });
            }
        }
        if let Some(result) = hit {
            self.rstats.count(RetrievalSource::CacheHit);
            // Keep the slot's speculative prediction tracking the *latest*
            // query, so a stale prefetch from before a run of cache hits
            // isn't later mis-counted as a bad prediction.
            if self.spec.as_ref().is_some_and(|s| !s.predicts(slot, query)) {
                self.issue_speculation(slot, query);
            }
            return Ok(CachedRetrieval { result, source: RetrievalSource::CacheHit });
        }
        // 2) Speculative prefetch verification (this slot's lane only).
        let t_verify = Instant::now();
        let verdict = match self.spec.as_mut() {
            Some(s) => s.verify_take(slot, query),
            None => SpecVerdict::Idle,
        };
        if trace_id != 0 && self.spec.is_some() {
            let spec_hit = matches!(&verdict, SpecVerdict::Hit(_));
            self.dispatcher.tracer.record(
                trace_id,
                SpanKind::SpecVerify,
                u32::from(spec_hit),
                t_verify.elapsed().as_secs_f64(),
            );
        }
        let (result, source) = match verdict {
            SpecVerdict::Hit(ticket) => {
                match self.dispatcher.poll(ticket, &self.index.pq.centroids) {
                    Some(r) => {
                        let result = self.search_to_result(r?, self.ds.nprobe, t0);
                        (result, RetrievalSource::SpecHit)
                    }
                    // Lost ticket (defensive): fall back to a real query.
                    None => {
                        (self.retrieve_with(query, trace_id, opts)?, RetrievalSource::Miss)
                    }
                }
            }
            SpecVerdict::Reject(ticket) => {
                self.dispatcher.cancel(ticket);
                (self.retrieve_with(query, trace_id, opts)?, RetrievalSource::Miss)
            }
            SpecVerdict::Idle => {
                (self.retrieve_with(query, trace_id, opts)?, RetrievalSource::Miss)
            }
        };
        // 3) Refill the cache with the fresh result — complete results
        // only: a degraded round's partial top-k must not masquerade as a
        // full answer on a later hit.
        if !result.is_partial() {
            if let Some(cache) =
                active_cache(&mut self.cache, &mut self.tenant_cache, tenant)
            {
                cache.insert(
                    query,
                    CachedEntry {
                        ids: result.ids.clone(),
                        dists: result.dists.clone(),
                        modeled_s: result.modeled_s,
                    },
                );
            }
        }
        // 4) Launch the next speculative query while the GPU decodes.
        self.issue_speculation(slot, query);
        self.rstats.count(source);
        Ok(CachedRetrieval { result, source })
    }

    /// Submit the predicted next query to the dispatcher (non-blocking)
    /// on `slot`'s ticket lane, replacing that slot's stale in-flight
    /// speculation only.
    fn issue_speculation(&mut self, slot: usize, query: &[f32]) {
        if self.spec.is_none() {
            return;
        }
        if let Some(old) = self.spec.as_mut().unwrap().take_in_flight(slot) {
            self.dispatcher.cancel(old);
        }
        let predicted = self.spec.as_mut().unwrap().slot_mut(slot).predict(query);
        let lists = self.index.probe(&predicted, self.ds.nprobe);
        let ticket =
            self.dispatcher.submit_for(slot, &predicted, &lists, self.ds.nprobe);
        self.spec.as_mut().unwrap().slot_mut(slot).set_in_flight(ticket, predicted);
    }

    /// Step 9: convert neighbor ids to next-tokens (decoder-only payload).
    pub fn gather_next_tokens(&self, ids: &[u64]) -> Vec<u32> {
        self.corpus.gather_next_tokens(ids)
    }

    /// Convert neighbor ids to concatenated chunks (EncDec payload).
    pub fn gather_chunks(&self, ids: &[u64]) -> Vec<u32> {
        self.corpus.gather_chunks(ids)
    }
}

/// The cache a request probes/refills: the tenant's slice when slicing is
/// on and the request names a tenant, else the shared cache. A free
/// function over the two fields (not a method) so the returned borrow
/// stays disjoint from `self.dispatcher` — the traced probe records spans
/// while the cache borrow is live.
fn active_cache<'a>(
    shared: &'a mut Option<RetrievalCache>,
    sliced: &'a mut Option<SlicedCache>,
    tenant: Option<u32>,
) -> Option<&'a mut RetrievalCache> {
    match (tenant, sliced.as_mut()) {
        (Some(t), Some(s)) => Some(s.slice_mut(t)),
        _ => shared.as_mut(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chamvs::node::{MemoryNode, ScanEngine};
    use crate::config::SIFT;
    use crate::data::synthetic::SyntheticDataset;
    use crate::ivf::shard::Shard;

    fn toy_retriever(n_nodes: usize) -> Retriever {
        let ds = SyntheticDataset::generate_sized(&SIFT, 2000, 4, 1);
        let index = IvfPqIndex::build(&ds.data, ds.n, ds.d, SIFT.m, 32, 2);
        let nodes = (0..n_nodes)
            .map(|i| {
                MemoryNode::new(Shard::carve(&index, i, n_nodes), ScanEngine::Native, 10)
            })
            .collect();
        let dispatcher = Dispatcher::new(nodes, 10);
        let corpus = Corpus::generate(2000, 2048, 8, 3);
        Retriever::new(&SIFT, index, dispatcher, corpus)
    }

    #[test]
    fn retrieve_returns_k_results() {
        let mut r = toy_retriever(2);
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let out = r.retrieve(ds.query(0)).unwrap();
        assert_eq!(out.ids.len(), 10);
        assert_eq!(out.dists.len(), 10);
        assert!(out.dists.windows(2).all(|w| w[0] <= w[1]));
        assert!(out.modeled_s > 0.0);
    }

    #[test]
    fn retrieve_many_matches_sequential_retrieves() {
        let mut r = toy_retriever(3);
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let want: Vec<RetrievalResult> =
            (0..4).map(|i| r.retrieve(ds.query(i)).unwrap()).collect();
        let refs: Vec<&[f32]> = (0..4).map(|i| ds.query(i)).collect();
        let got = r.retrieve_many(&refs).unwrap();
        assert_eq!(got.len(), 4);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.ids, w.ids);
            assert_eq!(g.dists, w.dists);
            assert!((g.modeled_s - w.modeled_s).abs() < 1e-12);
        }
    }

    #[test]
    fn tokens_follow_ids() {
        let r = toy_retriever(1);
        let toks = r.gather_next_tokens(&[0, 1, 2]);
        assert_eq!(toks.len(), 3);
        let chunks = r.gather_chunks(&[0, 1]);
        assert_eq!(chunks.len(), 16);
    }

    #[test]
    fn cached_retrieval_matches_uncached() {
        use crate::retcache::{CacheConfig, KeyPolicy, RetrievalSource};
        let mut r = toy_retriever(2);
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let q = ds.query(0);
        let want = r.retrieve(q).unwrap();
        r.enable_cache(CacheConfig { key: KeyPolicy::Exact, ..CacheConfig::default() });
        // First cached call: miss, runs the full path.
        let a = r.retrieve_cached(q).unwrap();
        assert_eq!(a.source, RetrievalSource::Miss);
        assert_eq!(a.result.ids, want.ids);
        // Second call: cache hit with identical payload + full modeled_s.
        let b = r.retrieve_cached(q).unwrap();
        assert_eq!(b.source, RetrievalSource::CacheHit);
        assert_eq!(b.result.ids, want.ids);
        assert_eq!(b.result.dists, want.dists);
        assert!((b.result.modeled_s - a.result.modeled_s).abs() < 1e-12);
        assert_eq!(r.rstats.misses, 1);
        assert_eq!(r.rstats.cache_hits, 1);
    }

    #[test]
    fn speculation_hits_on_repeated_query_without_cache() {
        use crate::retcache::{RetrievalSource, SpecConfig};
        let mut r = toy_retriever(1);
        r.enable_speculation(SpecConfig::default());
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let q = ds.query(1);
        let want = r.retrieve(q).unwrap();
        let a = r.retrieve_cached(q).unwrap();
        assert_eq!(a.source, RetrievalSource::Miss);
        assert_eq!(r.dispatcher.in_flight(), 1, "prefetch in flight");
        // Same query again: the prediction verifies and the prefetched
        // result is consumed, with identical numerics.
        let b = r.retrieve_cached(q).unwrap();
        assert_eq!(b.source, RetrievalSource::SpecHit);
        assert_eq!(b.result.ids, want.ids);
        assert_eq!(r.spec.as_ref().unwrap().verified(), 1);
        // A far-away query rejects the new in-flight prediction.
        let far = ds.query(2);
        let c = r.retrieve_cached(far).unwrap();
        assert_eq!(c.source, RetrievalSource::Miss);
        assert_eq!(r.spec.as_ref().unwrap().rejected(), 1);
        assert_eq!(r.dispatcher.in_flight(), 1, "stale prefetch cancelled");
        r.cancel_speculation();
        assert_eq!(r.dispatcher.in_flight(), 0);
    }

    #[test]
    fn cache_hit_keeps_prediction_fresh() {
        use crate::retcache::{CacheConfig, KeyPolicy, SpecConfig};
        let mut r = toy_retriever(1);
        r.enable_cache(CacheConfig { key: KeyPolicy::Exact, ..CacheConfig::default() });
        r.enable_speculation(SpecConfig::default());
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let q = ds.query(0);
        r.retrieve_cached(q).unwrap(); // miss -> prefetch predicting q
        assert_eq!(r.spec.as_ref().unwrap().issued(), 1);
        r.retrieve_cached(q).unwrap(); // hit, prediction already fresh
        assert_eq!(r.spec.as_ref().unwrap().issued(), 1, "no redundant reissue");
        assert_eq!(r.dispatcher.in_flight(), 1);
        // After serving a different query, a cache hit on q refreshes the
        // (now stale) prediction back to q instead of leaving it to rot.
        let q2 = ds.query(1);
        r.retrieve_cached(q2).unwrap(); // miss; stale prediction rejected
        assert!(r.spec.as_ref().unwrap().predicts(0, q2));
        r.retrieve_cached(q).unwrap(); // cache hit on q
        assert!(r.spec.as_ref().unwrap().predicts(0, q), "prediction refreshed");
        assert_eq!(r.dispatcher.in_flight(), 1);
    }

    #[test]
    fn tenant_sliced_cache_isolates_and_matches_uncached() {
        use crate::retcache::{CacheConfig, KeyPolicy, RetrievalSource};
        let mut r = toy_retriever(2);
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 16, 9);
        let q0 = ds.query(0);
        let want = r.retrieve(q0).unwrap();

        // Entries are 696 bytes here (d=128 exact key 512 + ids 80 +
        // dists 40 + overhead 64); the total budget holds ~5, re-divided
        // across tenants as they appear (2 entries per tenant at two).
        r.enable_tenant_cache(CacheConfig {
            capacity_bytes: 4096,
            key: KeyPolicy::Exact,
            ..CacheConfig::default()
        });
        assert!(r.retcache_enabled());

        // Tenant 0: miss then hit, bit-identical to the uncached path.
        let a = r.retrieve_cached_tenant_traced(0, Some(0), q0, 0).unwrap();
        assert_eq!(a.source, RetrievalSource::Miss);
        assert_eq!(a.result.ids, want.ids);
        let b = r.retrieve_cached_tenant_traced(0, Some(0), q0, 0).unwrap();
        assert_eq!(b.source, RetrievalSource::CacheHit);
        assert_eq!(b.result.ids, want.ids);
        assert_eq!(b.result.dists, want.dists);

        // A flooding batch tenant churns through its own slice only: the
        // interactive tenant's entry still hits afterwards.
        for round in 0..3 {
            for i in 1..10 {
                let cr = r
                    .retrieve_cached_tenant_traced(1, Some(1000), ds.query(i), 0)
                    .unwrap();
                if round == 0 && i == 1 {
                    assert_eq!(cr.source, RetrievalSource::Miss);
                }
            }
        }
        let tc = r.tenant_cache.as_ref().unwrap();
        assert_eq!(tc.n_tenants(), 2);
        assert!(tc.bytes() <= tc.total_capacity());
        let c = r.retrieve_cached_tenant_traced(0, Some(0), q0, 0).unwrap();
        assert_eq!(
            c.source,
            RetrievalSource::CacheHit,
            "flood must not evict the other tenant's entry"
        );

        // Tenant-less requests fall back to the shared cache (none here),
        // so they miss but still serve correctly.
        let d = r.retrieve_cached_from(0, q0).unwrap();
        assert_eq!(d.source, RetrievalSource::Miss);
        assert_eq!(d.result.ids, want.ids);
    }

    #[test]
    fn retcache_disabled_counts_nothing() {
        let mut r = toy_retriever(1);
        assert!(!r.retcache_enabled());
        let ds = SyntheticDataset::generate_sized(&SIFT, 10, 4, 9);
        let cr = r.retrieve_cached(ds.query(0)).unwrap();
        assert_eq!(cr.source, crate::retcache::RetrievalSource::Miss);
        assert_eq!(r.rstats.misses, 1);
        assert_eq!(r.dispatcher.in_flight(), 0, "no speculation issued");
    }

    #[test]
    fn self_query_finds_itself() {
        // Querying with database vector 0 must return id 0 first (PQ
        // distance to itself is minimal among clustered data).
        let mut r = toy_retriever(1);
        let q: Vec<f32> = r.index.pq.centroids[..0].to_vec(); // placeholder
        drop(q);
        let ds = SyntheticDataset::generate_sized(&SIFT, 2000, 4, 1);
        let out = r.retrieve(ds.vector(0)).unwrap();
        assert!(
            out.ids.contains(&0),
            "self id missing from {:?}",
            &out.ids[..5.min(out.ids.len())]
        );
    }
}
