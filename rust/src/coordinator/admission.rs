//! Tenant-aware admission control for the coordinator front door.
//!
//! Every request names a tenant (its `gpu_id`); tenants map onto two
//! scheduling classes reusing the load generator's convention —
//! interactive clients use small gpu ids, batch clients offset theirs by
//! [`BATCH_TENANT_BASE`]. Admission enforces, per tenant, a bounded
//! in-server queue and an optional token-bucket rate, and tells the
//! server exactly what to put in the `Backpressure` frame when it sheds.
//! Accepted requests are charged to the tenant until the dispatch loop
//! drains them ([`Admission::release`]), so the bound covers queued and
//! in-flight work, not just the batcher's queue.

use std::collections::HashMap;
use std::time::Instant;

use crate::cluster::engine::DegradedPolicy;
use crate::telemetry::SloObjective;

/// Tenant ids at or above this are batch-class (the `loadgen` convention:
/// interactive connection c sends gpu_id = c, batch sends 1000 + c).
pub const BATCH_TENANT_BASE: u32 = 1000;

/// Scheduling class of a tenant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosClass {
    /// Latency-sensitive: drains ahead of batch in every round.
    Interactive,
    /// Throughput-oriented: fills leftover batch slots, shed first.
    Batch,
}

impl QosClass {
    /// Class of a tenant id (the request's `gpu_id`).
    pub fn of_gpu(gpu_id: u32) -> QosClass {
        if gpu_id >= BATCH_TENANT_BASE {
            QosClass::Batch
        } else {
            QosClass::Interactive
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Interactive => "interactive",
            QosClass::Batch => "batch",
        }
    }
}

/// Why a request was shed (the `Backpressure.reason` wire code).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The tenant's bounded queue is full.
    QueueFull,
    /// The tenant's token bucket is empty.
    RateLimited,
    /// The request's end-to-end deadline budget expired while it waited
    /// in the server queue — running the round would waste cluster work
    /// on an answer the client has already written off.
    DeadlineExpired,
}

impl ShedReason {
    pub fn code(self) -> u32 {
        match self {
            ShedReason::QueueFull => 1,
            ShedReason::RateLimited => 2,
            ShedReason::DeadlineExpired => 3,
        }
    }
}

/// One shed decision: everything the server needs to fill a
/// `Backpressure` frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Shed {
    pub reason: ShedReason,
    /// Tenant queue depth at decision time.
    pub queue_depth: u32,
    /// Suggested client backoff before retrying, in microseconds.
    pub retry_after_us: u64,
}

/// Per-class admission knobs.
#[derive(Clone, Copy, Debug)]
pub struct TenantPolicy {
    /// Max requests a tenant may have queued + in flight; beyond this the
    /// server sheds with `QueueFull` instead of growing unboundedly.
    pub queue_cap: usize,
    /// Sustained admit rate in requests/s; <= 0 disables rate limiting.
    pub rate_qps: f64,
    /// Token-bucket burst size (floored at 1 when rate limiting is on).
    pub burst: f64,
}

impl TenantPolicy {
    pub fn unlimited_rate(queue_cap: usize) -> TenantPolicy {
        TenantPolicy { queue_cap, rate_qps: 0.0, burst: 0.0 }
    }
}

/// Front-door QoS configuration: per-class tenant policies plus the
/// event-loop shape knobs that ride along with them.
#[derive(Clone, Copy, Debug)]
pub struct QosConfig {
    pub interactive: TenantPolicy,
    pub batch: TenantPolicy,
    /// Poll threads in the concurrent server's fixed pool.
    pub poll_threads: usize,
    /// When true (the default) only the server's first accepted
    /// connection may issue `Shutdown`; other tenants' shutdown frames
    /// are counted and ignored.
    pub admin_shutdown_only: bool,
    /// How retrieval rounds treat unanswered shards. The default
    /// (`FailFast`) is the legacy contract — a reply is complete or the
    /// connection is dropped; `ServePartial` serves coverage-tagged
    /// partial results when replicas are dark or the deadline expires.
    pub degraded: DegradedPolicy,
    /// Latency/availability objective for interactive-class tenants;
    /// `None` (the default) records latency histograms but no burn
    /// rates.
    pub slo_interactive: Option<SloObjective>,
    /// Objective for batch-class tenants.
    pub slo_batch: Option<SloObjective>,
    /// When true, `StatsRequest` is honored only on the server's first
    /// accepted connection (the `admin_shutdown_only` gate, applied to
    /// the read-only stats plane). Off by default: stats expose no
    /// tenant payload data and `chameleon top` dials in as an ordinary
    /// connection.
    pub stats_admin_only: bool,
}

impl Default for QosConfig {
    fn default() -> Self {
        // Defaults are deliberately generous: existing single-tenant
        // tests and benches must never shed. Isolation tests tighten the
        // batch policy explicitly.
        QosConfig {
            interactive: TenantPolicy::unlimited_rate(4096),
            batch: TenantPolicy::unlimited_rate(1024),
            poll_threads: 2,
            admin_shutdown_only: true,
            degraded: DegradedPolicy::FailFast,
            slo_interactive: None,
            slo_batch: None,
            stats_admin_only: false,
        }
    }
}

impl QosConfig {
    pub fn policy(&self, class: QosClass) -> TenantPolicy {
        match class {
            QosClass::Interactive => self.interactive,
            QosClass::Batch => self.batch,
        }
    }
}

/// Token bucket refilled continuously at `rate` tokens/s up to `burst`.
#[derive(Clone, Copy, Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: Option<Instant>,
}

impl TokenBucket {
    pub fn new(rate_qps: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { rate: rate_qps, burst, tokens: burst, last: None }
    }

    /// Take one token at `now`; a bucket with rate <= 0 always grants.
    pub fn try_take(&mut self, now: Instant) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let dt = self
            .last
            .map(|t| now.saturating_duration_since(t).as_secs_f64())
            .unwrap_or(0.0);
        self.last = Some(now);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Microseconds until the next whole token exists (retry hint).
    pub fn micros_to_token(&self) -> u64 {
        if self.rate <= 0.0 || self.tokens >= 1.0 {
            return 0;
        }
        ((1.0 - self.tokens) / self.rate * 1e6).ceil() as u64
    }
}

struct TenantState {
    queued: usize,
    bucket: TokenBucket,
}

/// Admission state over all tenants seen so far.
pub struct Admission {
    cfg: QosConfig,
    tenants: HashMap<u32, TenantState>,
    shed: u64,
}

impl Admission {
    pub fn new(cfg: QosConfig) -> Admission {
        Admission { cfg, tenants: HashMap::new(), shed: 0 }
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Total requests shed so far (both reasons, all tenants).
    pub fn shed_count(&self) -> u64 {
        self.shed
    }

    /// Requests currently charged to `tenant` (queued or in flight).
    pub fn queued(&self, tenant: u32) -> usize {
        self.tenants.get(&tenant).map(|t| t.queued).unwrap_or(0)
    }

    /// Try to admit one request from `tenant`. Success charges the
    /// request to the tenant until [`release`](Self::release); failure
    /// returns the shed verdict for the `Backpressure` reply.
    pub fn admit(&mut self, tenant: u32, now: Instant) -> Result<(), Shed> {
        let pol = self.cfg.policy(QosClass::of_gpu(tenant));
        let st = self.tenants.entry(tenant).or_insert_with(|| TenantState {
            queued: 0,
            bucket: TokenBucket::new(pol.rate_qps, pol.burst),
        });
        if st.queued >= pol.queue_cap {
            self.shed += 1;
            return Err(Shed {
                reason: ShedReason::QueueFull,
                queue_depth: st.queued as u32,
                // One queue's worth of service time is unknowable here;
                // suggest a short fixed backoff — clients treat it as a
                // hint, not a contract.
                retry_after_us: 2_000,
            });
        }
        if !st.bucket.try_take(now) {
            self.shed += 1;
            return Err(Shed {
                reason: ShedReason::RateLimited,
                queue_depth: st.queued as u32,
                retry_after_us: st.bucket.micros_to_token().max(100),
            });
        }
        st.queued += 1;
        Ok(())
    }

    /// A previously admitted request left the server (served or its
    /// connection died before serving).
    pub fn release(&mut self, tenant: u32) {
        if let Some(st) = self.tenants.get_mut(&tenant) {
            st.queued = st.queued.saturating_sub(1);
        }
    }

    /// Current per-tenant charged depth, sorted by tenant id — the
    /// telemetry plane mirrors these into `admission.queued{tenant}`
    /// gauges on every scrape-visible update.
    pub fn depths(&self) -> Vec<(u32, usize)> {
        let mut v: Vec<(u32, usize)> =
            self.tenants.iter().map(|(t, st)| (*t, st.queued)).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn class_follows_the_loadgen_tenant_convention() {
        assert_eq!(QosClass::of_gpu(0), QosClass::Interactive);
        assert_eq!(QosClass::of_gpu(999), QosClass::Interactive);
        assert_eq!(QosClass::of_gpu(1000), QosClass::Batch);
        assert_eq!(QosClass::of_gpu(1003), QosClass::Batch);
    }

    #[test]
    fn queue_cap_shed_and_release_cycle() {
        let cfg = QosConfig {
            batch: TenantPolicy::unlimited_rate(2),
            ..QosConfig::default()
        };
        let mut a = Admission::new(cfg);
        let now = Instant::now();
        assert!(a.admit(1000, now).is_ok());
        assert!(a.admit(1000, now).is_ok());
        let shed = a.admit(1000, now).unwrap_err();
        assert_eq!(shed.reason, ShedReason::QueueFull);
        assert_eq!(shed.queue_depth, 2);
        assert!(shed.retry_after_us > 0);
        assert_eq!(a.queued(1000), 2);
        assert_eq!(a.shed_count(), 1);

        // Draining one admits the next; release never underflows.
        a.release(1000);
        assert!(a.admit(1000, now).is_ok());
        for _ in 0..5 {
            a.release(1000);
        }
        assert_eq!(a.queued(1000), 0);
        a.release(42); // unknown tenant is a no-op
    }

    #[test]
    fn tenants_are_isolated_from_each_other() {
        let cfg = QosConfig {
            batch: TenantPolicy::unlimited_rate(1),
            ..QosConfig::default()
        };
        let mut a = Admission::new(cfg);
        let now = Instant::now();
        assert!(a.admit(1000, now).is_ok());
        assert!(a.admit(1000, now).is_err(), "flooder at its cap");
        // A different batch tenant and an interactive tenant still admit.
        assert!(a.admit(1001, now).is_ok());
        assert!(a.admit(0, now).is_ok());
        assert_eq!(a.queued(1000), 1);
        assert_eq!(a.queued(1001), 1);
    }

    #[test]
    fn token_bucket_refills_at_the_configured_rate() {
        let mut b = TokenBucket::new(10.0, 2.0);
        let t0 = Instant::now();
        // Burst of 2, then dry.
        assert!(b.try_take(t0));
        assert!(b.try_take(t0));
        assert!(!b.try_take(t0));
        let hint = b.micros_to_token();
        assert!(hint > 0 && hint <= 100_000, "hint {hint}us at 10 qps");
        // 100 ms at 10 tokens/s buys exactly one more.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.try_take(t1));
        assert!(!b.try_take(t1));
    }

    #[test]
    fn default_config_never_sheds_a_modest_workload() {
        let mut a = Admission::new(QosConfig::default());
        let now = Instant::now();
        for _ in 0..1000 {
            assert!(a.admit(0, now).is_ok());
            assert!(a.admit(1000, now).is_ok());
            a.release(0);
            a.release(1000);
        }
        assert_eq!(a.shed_count(), 0);
    }

    #[test]
    fn rate_limit_sheds_with_a_retry_hint() {
        let cfg = QosConfig {
            batch: TenantPolicy { queue_cap: 100, rate_qps: 5.0, burst: 1.0 },
            ..QosConfig::default()
        };
        let mut a = Admission::new(cfg);
        let now = Instant::now();
        assert!(a.admit(1000, now).is_ok());
        let shed = a.admit(1000, now).unwrap_err();
        assert_eq!(shed.reason, ShedReason::RateLimited);
        assert!(shed.retry_after_us >= 100);
        // Interactive stays unlimited under the same config.
        for _ in 0..50 {
            assert!(a.admit(7, now).is_ok());
        }
    }
}
