//! The end-to-end RALM engine: worker pool + retriever + batching —
//! what `chameleon serve` and the Fig 11/12 benches drive.

use anyhow::Result;

use crate::chamlm::generator::{GenerationStats, Generator};
use crate::chamlm::pool::WorkerPool;
use crate::chamlm::sampler::Sampler;
use crate::chamvs::backend::ScanBackend;
use crate::config::ModelConfig;
use crate::coordinator::retriever::Retriever;
use crate::hwmodel::gpu::GpuModel;
use crate::retcache::{CacheConfig, SpecConfig, CACHE_LOOKUP_S};

/// Serving-side statistics for a batch of sequences.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub sequences: usize,
    pub tokens: usize,
    /// Modeled paper-scale wall time for the batch (gated by the slowest
    /// stage per step).
    pub modeled_s: f64,
    /// Host wall-clock actually spent.
    pub measured_s: f64,
    pub per_sequence: Vec<GenerationStats>,
}

impl ServeStats {
    pub fn modeled_tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.modeled_s.max(1e-12)
    }
}

/// End-to-end engine: a model served against a retriever.
pub struct RalmEngine {
    pub pool: WorkerPool,
    pub retriever: Retriever,
    pub sampler: Sampler,
    /// The paper-scale model this scaled deployment stands in for
    /// (drives the modeled latencies; same architecture family).
    pub paper_model: &'static ModelConfig,
    pub gpu: GpuModel,
}

impl RalmEngine {
    pub fn new(
        pool: WorkerPool,
        retriever: Retriever,
        paper_model: &'static ModelConfig,
    ) -> RalmEngine {
        RalmEngine {
            pool,
            retriever,
            sampler: Sampler::TopK(32, 1.0),
            paper_model,
            gpu: GpuModel::default(),
        }
    }

    /// Turn on the retrieval cache and/or speculative prefetching for the
    /// serving path (see the `retcache` module).
    pub fn enable_retcache(&mut self, cache: Option<CacheConfig>, spec: Option<SpecConfig>) {
        if let Some(c) = cache {
            self.retriever.enable_cache(c);
        }
        if let Some(s) = spec {
            self.retriever.enable_speculation(s);
        }
    }

    /// Retcache counter block for the serve report (empty when disabled).
    pub fn cache_report(&self) -> String {
        if self.retriever.retcache_enabled() {
            self.retriever.cache_report()
        } else {
            String::new()
        }
    }

    /// Generate one sequence of `n_tokens` and return its stats. The
    /// sequence runs on the next round-robin worker, whose GPU id is the
    /// speculation slot: each worker owns an independent prefetch lane on
    /// the dispatcher (submit/poll/cancel isolation across GPUs).
    pub fn generate(&mut self, prompt: u32, n_tokens: usize, seed: u64) -> Result<GenerationStats> {
        let modeled_decode = self.gpu.decode_step_latency(self.paper_model, 1);
        let modeled_encode = self.gpu.encode_latency(self.paper_model, 1);
        let worker = self.pool.next_worker();
        let slot = worker.id;
        // A speculative prefetch predicted from a previous sequence on
        // THIS stream would only pollute verification — drop it at the
        // boundary. Other workers' lanes stay in flight.
        self.retriever.cancel_slot_speculation(slot);
        let mut gen = Generator {
            worker,
            slot,
            retriever: &mut self.retriever,
            sampler: self.sampler,
            modeled_decode_s: modeled_decode,
            modeled_encode_s: modeled_encode,
        };
        gen.generate(prompt, n_tokens, seed)
    }

    /// Serve a batch of sequences (Fig 12 setup: all sequences generate
    /// `n_tokens`; modeled time assumes batched GPU decode + batched
    /// retrieval as in the paper's throughput experiments).
    pub fn serve_batch(
        &mut self,
        prompts: &[u32],
        n_tokens: usize,
        seed: u64,
    ) -> Result<ServeStats> {
        let b = prompts.len();
        let t0 = std::time::Instant::now();
        let rstats_before = self.retriever.rstats;
        let mut per_sequence = Vec::with_capacity(b);
        for (i, &p) in prompts.iter().enumerate() {
            per_sequence.push(self.generate(p, n_tokens, seed ^ i as u64)?);
        }
        // Modeled batch time: per step, the GPU runs the whole batch in
        // one decode; retrieval requests are batched to ChamVS.
        let decode_s = self.gpu.decode_step_latency(self.paper_model, b);
        let interval = self.paper_model.interval.max(1);
        let retr_per_step = {
            // Batched retrieval: b queries pipelined through the FPGA.
            let fpga = self.retriever.dispatcher.fpga();
            let ds = self.retriever.ds;
            let paper_codes = (ds.n_paper as f64 * ds.nprobe as f64
                / ds.nlist_paper as f64) as usize;
            let per_node =
                paper_codes / self.retriever.dispatcher.fan_out().max(1);
            fpga.batch_latency(b, per_node, ds.m, ds.nprobe, self.retriever.k())
        };
        let encode_s = if self.paper_model.is_encdec() {
            self.gpu.encode_latency(self.paper_model, b)
        } else {
            0.0
        };
        let steps = n_tokens as f64;
        let retrieval_steps = (n_tokens as f64 / interval as f64).ceil();
        // Cache-aware accounting: charge retrieval steps by how this
        // batch's retrievals were actually served. With retcache disabled
        // no sources are counted and this reduces to the seed formula
        // (decode + full batched retrieval every interval).
        let d = self.retriever.rstats.delta_since(&rstats_before);
        let retr_charged = if d.total() == 0 {
            retr_per_step
        } else {
            let overlap = self.retriever.overlap_window_s(decode_s, interval);
            let residual = (retr_per_step - overlap).max(0.0);
            (d.misses as f64 * retr_per_step
                + d.spec_hits as f64 * (CACHE_LOOKUP_S + residual)
                + d.cache_hits as f64 * CACHE_LOOKUP_S)
                / d.total() as f64
        };
        let modeled_s =
            steps * decode_s + retrieval_steps * (retr_charged + encode_s);
        Ok(ServeStats {
            sequences: b,
            tokens: b * n_tokens,
            modeled_s,
            measured_s: t0.elapsed().as_secs_f64(),
            per_sequence,
        })
    }
}
