//! Dynamic request batcher: the coordinator groups retrieval requests
//! arriving from GPU processes before broadcasting to the memory nodes
//! (paper Sec 3; batching behaviour drives the Fig 9/12 batch sweeps).

use std::time::{Duration, Instant};

/// A pending request tagged with its source (paper: "records the
/// association between queries and GPU IDs").
#[derive(Clone, Debug, PartialEq)]
pub struct Pending<T> {
    pub source_gpu: usize,
    pub payload: T,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) }
    }
}

/// A dynamic batcher accumulating requests until the policy fires.
pub struct DynamicBatcher<T> {
    pub policy: BatchPolicy,
    queue: Vec<Pending<T>>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher { policy, queue: Vec::new() }
    }

    pub fn push(&mut self, source_gpu: usize, payload: T) {
        self.queue.push(Pending { source_gpu, payload, arrived: Instant::now() });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether the policy says "dispatch now".
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        self.queue
            .first()
            .map(|p| now.duration_since(p.arrived) >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// Take up to `max_batch` requests (FIFO).
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }
}

/// Tracks which request source (GPU id) the retriever's in-flight
/// speculative prefetch belongs to. The coordinator overlaps prefetch
/// with the *issuing* GPU's decode steps; when requests from different
/// GPUs interleave on one retriever, a prediction made for GPU A must not
/// be verified against GPU B's query — the server cancels it instead
/// (see `coordinator::server` and the retcache module).
#[derive(Debug, Default)]
pub struct PrefetchTracker {
    owner: Option<usize>,
    /// Source switches observed (each one cancels an in-flight prefetch).
    pub switches: u64,
}

impl PrefetchTracker {
    pub fn new() -> PrefetchTracker {
        PrefetchTracker::default()
    }

    /// Record a retrieval from `source`. Returns true when an in-flight
    /// prefetch belongs to a *different* source and must be cancelled
    /// before this retrieval runs.
    pub fn observe(&mut self, source: usize) -> bool {
        let switch = self.owner.is_some_and(|o| o != source);
        if switch {
            self.switches += 1;
        }
        self.owner = Some(source);
        switch
    }

    /// Forget the current owner (connection teardown, cache reset).
    pub fn reset(&mut self) {
        self.owner = None;
    }

    pub fn owner(&self) -> Option<usize> {
        self.owner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_tracker_flags_source_switches() {
        let mut t = PrefetchTracker::new();
        assert!(!t.observe(0), "first source never cancels");
        assert!(!t.observe(0), "same source keeps its prefetch");
        assert!(t.observe(1), "switch cancels");
        assert!(!t.observe(1));
        assert!(t.observe(0));
        assert_eq!(t.switches, 2);
        assert_eq!(t.owner(), Some(0));
        t.reset();
        assert_eq!(t.owner(), None);
        assert!(!t.observe(2), "reset forgets the owner");
    }

    #[test]
    fn fires_on_size() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        b.push(0, "a");
        assert!(!b.ready(Instant::now()));
        b.push(1, "b");
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn fires_on_timeout() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(0, 42u32);
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn fifo_order_and_partial_take() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        for i in 0..5 {
            b.push(i, i);
        }
        let batch = b.take_batch();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn empty_never_ready() {
        let b: DynamicBatcher<u8> = DynamicBatcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
    }
}
