//! Dynamic request batcher: the coordinator groups retrieval requests
//! arriving from GPU processes before broadcasting to the memory nodes
//! (paper Sec 3; batching behaviour drives the Fig 9/12 batch sweeps).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::admission::QosClass;

/// A pending request tagged with its source (paper: "records the
/// association between queries and GPU IDs").
#[derive(Clone, Debug, PartialEq)]
pub struct Pending<T> {
    pub source_gpu: usize,
    pub payload: T,
    pub arrived: Instant,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_micros(200) }
    }
}

/// A dynamic batcher accumulating requests until the policy fires.
///
/// The queue is a `VecDeque`: every dispatch round pops from the front,
/// and a `Vec` would shift the whole backlog left on each round — O(n)
/// per round, quadratic over a deep backlog (admission control bounds
/// the depth, but the head-drain must stay O(batch) regardless).
pub struct DynamicBatcher<T> {
    pub policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> DynamicBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, source_gpu: usize, payload: T) {
        self.queue.push_back(Pending {
            source_gpu,
            payload,
            arrived: Instant::now(),
        });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether the policy says "dispatch now".
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        self.queue
            .front()
            .map(|p| now.duration_since(p.arrived) >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// How long until the oldest queued request hits `max_wait` (zero if
    /// already overdue, `None` when the queue is empty) — the condvar
    /// timeout of the coordinator's dispatch loop.
    pub fn time_to_ready(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            self.policy.max_wait.saturating_sub(now.duration_since(p.arrived))
        })
    }

    /// Take up to `n` requests from the head (FIFO).
    pub fn take_n(&mut self, n: usize) -> Vec<Pending<T>> {
        let n = self.queue.len().min(n);
        self.queue.drain(..n).collect()
    }

    /// Take up to `max_batch` requests (FIFO).
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        self.take_n(self.policy.max_batch)
    }

    /// Take one dispatch round and group it by source GPU, preserving
    /// FIFO order within each group — the shape `Dispatcher::search_batch`
    /// consumes when per-GPU response queues matter (each group's results
    /// return to one client stream).
    pub fn take_batch_grouped(&mut self) -> Vec<(usize, Vec<Pending<T>>)> {
        let mut groups: Vec<(usize, Vec<Pending<T>>)> = Vec::new();
        for p in self.take_batch() {
            match groups.iter_mut().find(|(src, _)| *src == p.source_gpu) {
                Some((_, g)) => g.push(p),
                None => groups.push((p.source_gpu, vec![p])),
            }
        }
        groups
    }
}

/// Two-lane priority batcher: interactive requests ride a separate queue
/// that drains ahead of the batch class in every dispatch round, with
/// batch-class requests filling whatever slots remain up to `max_batch`.
/// Each lane keeps FIFO order, so a flooding batch tenant can delay an
/// interactive request by at most one in-flight round — the scheduling
/// half of tenant isolation (admission bounds the queue depths).
pub struct ClassedBatcher<T> {
    interactive: DynamicBatcher<T>,
    batch: DynamicBatcher<T>,
}

impl<T> ClassedBatcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        ClassedBatcher {
            interactive: DynamicBatcher::new(policy),
            batch: DynamicBatcher::new(policy),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.interactive.policy
    }

    pub fn push(&mut self, class: QosClass, source_gpu: usize, payload: T) {
        match class {
            QosClass::Interactive => self.interactive.push(source_gpu, payload),
            QosClass::Batch => self.batch.push(source_gpu, payload),
        }
    }

    pub fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.batch.is_empty()
    }

    /// Queued requests in the given lane (observability / shed hints).
    pub fn lane_len(&self, class: QosClass) -> usize {
        match class {
            QosClass::Interactive => self.interactive.len(),
            QosClass::Batch => self.batch.len(),
        }
    }

    /// Dispatch now when either lane's policy fires, or when the lanes
    /// together already fill a round.
    pub fn ready(&self, now: Instant) -> bool {
        self.interactive.ready(now)
            || self.batch.ready(now)
            || self.len() >= self.policy().max_batch
    }

    /// Condvar timeout for the dispatch loop: the nearer of the two
    /// lanes' deadlines.
    pub fn time_to_ready(&self, now: Instant) -> Option<Duration> {
        match (self.interactive.time_to_ready(now), self.batch.time_to_ready(now))
        {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, b) => b,
        }
    }

    /// Take one round: the interactive lane drains first (FIFO), then
    /// batch-class requests fill the remaining slots (FIFO).
    pub fn take_batch(&mut self) -> Vec<Pending<T>> {
        let cap = self.policy().max_batch;
        let mut out = self.interactive.take_n(cap);
        let mut fill = self.batch.take_n(cap - out.len());
        out.append(&mut fill);
        out
    }
}

/// Tracks which request sources (GPU ids) are active on one connection
/// loop, and how often consecutive requests switch sources.
///
/// With per-GPU speculation slots (`retcache::SpecSlots`) a source switch
/// no longer cancels the in-flight prefetch — each source owns an
/// isolated ticket lane on the dispatcher — but the switch rate stays a
/// useful interleaving signal, and the seen-source set tells the server
/// exactly which slots to cancel at connection teardown.
#[derive(Debug, Default)]
pub struct PrefetchTracker {
    last: Option<usize>,
    seen: Vec<usize>,
    /// Source switches observed (stream interleave points).
    pub switches: u64,
}

impl PrefetchTracker {
    pub fn new() -> PrefetchTracker {
        PrefetchTracker::default()
    }

    /// Record a retrieval from `source`. Returns true when the source
    /// differs from the previous request's (a stream interleave point —
    /// informational now that slots isolate the prefetch lanes).
    pub fn observe(&mut self, source: usize) -> bool {
        let switch = self.last.is_some_and(|o| o != source);
        if switch {
            self.switches += 1;
        }
        self.last = Some(source);
        if !self.seen.contains(&source) {
            self.seen.push(source);
        }
        switch
    }

    /// Every source seen since the last reset (the slot ids a teardown
    /// must cancel), in first-seen order.
    pub fn sources(&self) -> &[usize] {
        &self.seen
    }

    /// Forget all sources (connection teardown, cache reset).
    pub fn reset(&mut self) {
        self.last = None;
        self.seen.clear();
    }

    /// The most recent source (None before any request / after reset).
    pub fn owner(&self) -> Option<usize> {
        self.last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_tracker_flags_source_switches() {
        let mut t = PrefetchTracker::new();
        assert!(!t.observe(0), "first source is never a switch");
        assert!(!t.observe(0), "same source is not a switch");
        assert!(t.observe(1), "interleave point");
        assert!(!t.observe(1));
        assert!(t.observe(0));
        assert_eq!(t.switches, 2);
        assert_eq!(t.owner(), Some(0));
        assert_eq!(t.sources(), &[0, 1], "seen set in first-seen order");
        t.reset();
        assert_eq!(t.owner(), None);
        assert!(t.sources().is_empty());
        assert!(!t.observe(2), "reset forgets the sources");
    }

    #[test]
    fn take_batch_grouped_preserves_order_within_source() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 6,
            max_wait: Duration::from_secs(1),
        });
        for (src, payload) in [(0, 'a'), (1, 'b'), (0, 'c'), (2, 'd'), (1, 'e')] {
            b.push(src, payload);
        }
        let groups = b.take_batch_grouped();
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].0, 0);
        assert_eq!(
            groups[0].1.iter().map(|p| p.payload).collect::<Vec<_>>(),
            vec!['a', 'c']
        );
        assert_eq!(groups[1].0, 1);
        assert_eq!(
            groups[1].1.iter().map(|p| p.payload).collect::<Vec<_>>(),
            vec!['b', 'e']
        );
        assert_eq!(groups[2].0, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn fires_on_size() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        b.push(0, "a");
        assert!(!b.ready(Instant::now()));
        b.push(1, "b");
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn fires_on_timeout() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(0, 42u32);
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
    }

    #[test]
    fn fifo_order_and_partial_take() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_secs(1),
        });
        for i in 0..5 {
            b.push(i, i);
        }
        let batch = b.take_batch();
        assert_eq!(batch.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn empty_never_ready() {
        let b: DynamicBatcher<u8> = DynamicBatcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.time_to_ready(Instant::now()), None);
    }

    #[test]
    fn time_to_ready_counts_down_to_zero() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(50),
        });
        b.push(0, 1u32);
        let now = Instant::now();
        let left = b.time_to_ready(now).unwrap();
        assert!(left <= Duration::from_millis(50));
        // Far past the deadline the remaining wait saturates at zero.
        let later = now + Duration::from_millis(500);
        assert_eq!(b.time_to_ready(later), Some(Duration::ZERO));
        assert!(b.ready(later));
    }

    #[test]
    fn deep_backlog_drains_fifo_in_batch_rounds() {
        // The head-drain regression pin: a deep backlog must come out in
        // exact FIFO order, full rounds at a time, and grouped rounds must
        // behave identically to before the VecDeque switch.
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_secs(1),
        });
        let n = 10_000;
        for i in 0..n {
            b.push(i % 3, i);
        }
        let mut seen = Vec::with_capacity(n);
        while !b.is_empty() {
            let round = b.take_batch();
            assert!(round.len() <= 16);
            assert!(round.len() == 16 || b.is_empty());
            seen.extend(round.iter().map(|p| p.payload));
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>());

        // Same backlog through the grouped take: round contents unchanged
        // (one round = the next 16 in FIFO order, split by source, order
        // preserved within each source group).
        for i in 0..48 {
            b.push(i % 3, i);
        }
        let groups = b.take_batch_grouped();
        let mut flat: Vec<usize> = Vec::new();
        for (src, g) in &groups {
            for p in g {
                assert_eq!(p.source_gpu, *src);
                flat.push(p.payload);
            }
        }
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>(), "round = FIFO head");
        for (_, g) in &groups {
            for w in g.windows(2) {
                assert!(w[0].payload < w[1].payload, "within-source FIFO");
            }
        }
        assert_eq!(b.len(), 32);
    }

    #[test]
    fn classed_batcher_serves_interactive_first() {
        let mut b = ClassedBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(1),
        });
        // A batch-class flood ahead of two interactive arrivals.
        for i in 0..10 {
            b.push(QosClass::Batch, 1000, i);
        }
        b.push(QosClass::Interactive, 0, 100);
        b.push(QosClass::Interactive, 0, 101);
        assert_eq!(b.len(), 12);
        assert_eq!(b.lane_len(QosClass::Interactive), 2);
        assert!(b.ready(Instant::now()), "combined depth fills a round");

        // Round 1: interactive head-of-line, batch fills the remainder.
        let round: Vec<usize> =
            b.take_batch().iter().map(|p| p.payload).collect();
        assert_eq!(round, vec![100, 101, 0, 1]);
        // Subsequent rounds drain the batch lane FIFO.
        let round: Vec<usize> =
            b.take_batch().iter().map(|p| p.payload).collect();
        assert_eq!(round, vec![2, 3, 4, 5]);
        assert_eq!(b.lane_len(QosClass::Batch), 4);
    }

    #[test]
    fn classed_batcher_deadline_is_the_nearer_lane() {
        let mut b = ClassedBatcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(50),
        });
        assert_eq!(b.time_to_ready(Instant::now()), None);
        assert!(!b.ready(Instant::now()));
        b.push(QosClass::Batch, 1000, 1u32);
        std::thread::sleep(Duration::from_millis(2));
        b.push(QosClass::Interactive, 0, 2u32);
        let now = Instant::now();
        // The batch request arrived first, so its deadline is nearer.
        let left = b.time_to_ready(now).unwrap();
        assert!(left <= Duration::from_millis(50));
        let later = now + Duration::from_millis(500);
        assert!(b.ready(later), "overdue lane fires the round");
    }
}
