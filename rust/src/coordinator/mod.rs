//! The CPU coordinator server (paper Sec 3): routes queries between the
//! LLM side (ChamLM) and the retrieval side (ChamVS), converts retrieved
//! vector IDs into tokens, batches requests across client connections,
//! and hosts the end-to-end RALM engine used by the examples and benches.

pub mod batcher;
pub mod engine;
pub mod ratio;
pub mod retriever;
pub mod server;

pub use batcher::{BatchPolicy, DynamicBatcher, PrefetchTracker};
pub use engine::RalmEngine;
pub use retriever::{CachedRetrieval, RetrievalResult, Retriever};
pub use server::{CoordinatorClient, CoordinatorServer, ServeMode, ServerStats};
