//! The CPU coordinator server (paper Sec 3): routes queries between the
//! LLM side (ChamLM) and the retrieval side (ChamVS), converts retrieved
//! vector IDs into tokens, batches requests across client connections,
//! and hosts the end-to-end RALM engine used by the examples and benches.

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod ratio;
pub mod retriever;
pub mod server;

pub use admission::{Admission, QosClass, QosConfig, ShedReason, TenantPolicy};
pub use crate::telemetry::SloObjective;
pub use batcher::{BatchPolicy, ClassedBatcher, DynamicBatcher, PrefetchTracker};
pub use engine::RalmEngine;
pub use retriever::{CachedRetrieval, RetrievalResult, Retriever};
pub use server::{CoordinatorClient, CoordinatorServer, Reply, ServeMode, ServerStats};
