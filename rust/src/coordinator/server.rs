//! The coordinator as a network service: GPU clients submit retrieval
//! requests over TCP; the coordinator fans them out to the memory nodes,
//! k-way-merges results, converts vector ids to tokens, and replies
//! (paper Sec 3, workflow steps 3-9 — the "CPU coordinator server").

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::batcher::PrefetchTracker;
use crate::coordinator::retriever::Retriever;
use crate::net::protocol::{Frame, Kind, RetrieveRequest, RetrieveResponse};
use crate::util::metrics::Metrics;

/// A running coordinator server.
pub struct CoordinatorServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CoordinatorServer {
    /// Spawn the coordinator on an ephemeral local port. The retriever is
    /// built on the server thread (PJRT engines are not Send).
    pub fn spawn_with(
        builder: impl FnOnce() -> Retriever + Send + 'static,
    ) -> Result<CoordinatorServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let mut retriever = builder();
            let metrics = Metrics::new();
            let mut prefetch = PrefetchTracker::new();
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let _ = serve_gpu(
                            stream,
                            &mut retriever,
                            &metrics,
                            &mut prefetch,
                            &stop2,
                        );
                        // Connection teardown: cancel exactly the slots this
                        // connection's GPU sources touched, so a departed
                        // client's predictions never verify against whoever
                        // connects next (other connections' lanes untouched).
                        for &slot in prefetch.sources() {
                            retriever.cancel_slot_speculation(slot);
                        }
                        prefetch.reset();
                        if stop2.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            if retriever.retcache_enabled() {
                retriever.export_metrics(&metrics);
            }
            eprintln!("[coordinator] metrics:\n{}", metrics.render());
        });
        Ok(CoordinatorServer { addr, stop, handle: Some(handle) })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_gpu(
    stream: TcpStream,
    retriever: &mut Retriever,
    metrics: &Metrics,
    prefetch: &mut PrefetchTracker,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match Frame::read_from(&mut reader) {
            Ok(f) => f,
            Err(e) => {
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out {
                    continue;
                }
                return Ok(());
            }
        };
        match frame.kind {
            Kind::Shutdown => {
                stop.store(true, Ordering::Relaxed);
                return Ok(());
            }
            Kind::RetrieveRequest => {
                let req = RetrieveRequest::decode(&frame)?;
                metrics.incr("retrieve_requests", 1);
                metrics.incr(&format!("gpu_{}_requests", req.gpu_id), 1);
                // Retcache path: each GPU source owns its own speculation
                // slot, so interleaved sources no longer cancel each
                // other's prefetches — the switch rate is kept as an
                // interleaving metric only.
                let slot = req.gpu_id as usize;
                if prefetch.observe(slot) {
                    metrics.incr("retcache.prefetch_source_switches", 1);
                }
                let r = if retriever.retcache_enabled() {
                    let cr = metrics.time("retrieve", || {
                        retriever.retrieve_cached_from(slot, &req.query)
                    })?;
                    metrics.incr(
                        match cr.source {
                            crate::retcache::RetrievalSource::Miss => "retrieve_miss",
                            crate::retcache::RetrievalSource::CacheHit => {
                                "retrieve_cache_hit"
                            }
                            crate::retcache::RetrievalSource::SpecHit => {
                                "retrieve_spec_hit"
                            }
                        },
                        1,
                    );
                    cr.result
                } else {
                    metrics.time("retrieve", || retriever.retrieve(&req.query))?
                };
                let tokens = if req.want_chunks {
                    retriever.gather_chunks(&r.ids)
                } else {
                    retriever.gather_next_tokens(&r.ids)
                };
                let resp = RetrieveResponse {
                    query_id: req.query_id,
                    tokens,
                    dists: r.dists,
                };
                resp.encode().write_to(&mut writer)?;
            }
            other => anyhow::bail!("unexpected frame {other:?} at coordinator"),
        }
    }
}

/// GPU-process-side client of the coordinator.
pub struct CoordinatorClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    pub gpu_id: u32,
    next_id: u64,
}

impl CoordinatorClient {
    pub fn connect(addr: SocketAddr, gpu_id: u32) -> Result<CoordinatorClient> {
        let stream =
            TcpStream::connect(addr).context("connecting to coordinator")?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(CoordinatorClient { stream, reader, gpu_id, next_id: 0 })
    }

    /// One blocking retrieval round trip (the per-token path for
    /// decoder-only models).
    pub fn retrieve(
        &mut self,
        query: &[f32],
        lists: &[u32],
        k: usize,
        want_chunks: bool,
    ) -> Result<RetrieveResponse> {
        let id = self.next_id;
        self.next_id += 1;
        RetrieveRequest {
            query_id: id,
            gpu_id: self.gpu_id,
            query: query.to_vec(),
            lists: lists.to_vec(),
            k: k as u32,
            want_chunks,
        }
        .encode()
        .write_to(&mut self.stream)?;
        let f = Frame::read_from(&mut self.reader)?;
        let resp = RetrieveResponse::decode(&f)?;
        anyhow::ensure!(resp.query_id == id, "response id mismatch");
        Ok(resp)
    }

    pub fn shutdown_coordinator(&mut self) {
        let _ = Frame { kind: Kind::Shutdown, payload: vec![] }.write_to(&mut self.stream);
    }
}
