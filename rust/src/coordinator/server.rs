//! The coordinator as a network service: GPU clients submit retrieval
//! requests over TCP; the coordinator fans them out to the memory nodes,
//! k-way-merges results, converts vector ids to tokens, and replies
//! (paper Sec 3, workflow steps 3-9 — the "CPU coordinator server").
//!
//! Three serving modes ([`ServeMode`]):
//!
//! * **Concurrent** (the default) — a nonblocking event loop: a small
//!   fixed pool of poll threads watches all connections with readiness
//!   polling ([`crate::util::poll`]); each connection owns a
//!   [`FrameReader`] that decodes frames *incrementally*, buffering
//!   partial header/payload bytes across readiness events, so a slow or
//!   dribbling client can never desync the stream. Decoded
//!   [`RetrieveRequest`]s pass tenant-aware admission control
//!   ([`Admission`]: per-tenant bounded queues + token buckets; sheds
//!   reply with an explicit [`Backpressure`] frame) and land in a
//!   two-lane [`ClassedBatcher`] (interactive drains ahead of batch). A
//!   single dispatch loop (which owns the [`Retriever`]) drains
//!   cross-connection batches when the [`BatchPolicy`] fires, runs them
//!   through one parallel round to the memory nodes, and routes each
//!   reply back to its owning connection by request id. A connection's
//!   *retrieval replies* keep FIFO order, so clients may pipeline;
//!   `Backpressure` replies are written at admission time and may
//!   interleave (match by `query_id`). Thread count is fixed — accept +
//!   poll pool + dispatch — regardless of how many clients connect.
//! * **Threaded** — the previous concurrent server: one blocking reader
//!   thread per connection feeding the same batcher. Kept for A/B
//!   measurement of the event loop (`benches/coordinator_throughput.rs`).
//! * **Sequential** — the pre-batching baseline: one connection served to
//!   completion at a time on the accept thread (`chameleon serve --net
//!   --sequential`).
//!
//! `Shutdown` frames are accepted only from the server's first connection
//! by default ([`QosConfig::admin_shutdown_only`]) — any other tenant's
//! shutdown is counted ([`ServerStats::shutdown_denied`]) and ignored, so
//! one misbehaving client cannot kill everyone else's server.
//!
//! When the retriever dispatches over a replicated cluster (see
//! [`crate::cluster`]), `ClusterUpdate` frames drive live membership
//! transitions: the dispatch loop applies them strictly *between*
//! batches, so epochs swap without dropping in-flight requests, and the
//! admin connection receives a `ClusterAck` with the new epoch.

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::engine::{ClusterNode, RoundOptions};
use crate::coordinator::admission::{Admission, QosClass, QosConfig, ShedReason};
use crate::coordinator::batcher::{BatchPolicy, ClassedBatcher, Pending, PrefetchTracker};
use crate::coordinator::retriever::{RetrievalResult, Retriever};
use crate::net::client::RemoteNode;
use crate::net::protocol::{
    Backpressure, ClusterAck, ClusterOp, ClusterUpdate, Frame, FrameReader, Kind,
    ReadProgress, RetrieveRequest, RetrieveResponse, StatsRequest, StatsResponse,
    STATS_REVISION,
};
use crate::retcache::RetrievalSource;
use crate::telemetry::{Counter, Gauge, Outcome, Registry, Telemetry, TelemetryConfig};
use crate::trace::{SpanKind, Tracer};
use crate::util::json::{obj, Json};
use crate::util::metrics::Metrics;
use crate::util::poll::{raw_fd, wait_readable, wait_writable};

/// How idle loops poll their stop flags.
const POLL: Duration = Duration::from_millis(25);

/// Readiness-wait granularity of the event loop's poll threads (also how
/// fast they notice the stop flag and adopt new connections).
const EVENT_POLL: Duration = Duration::from_millis(10);

/// Hard bound on how long one reply write may stall on a congested peer
/// before the connection is declared dead.
const WRITE_LIMIT: Duration = Duration::from_secs(5);

/// How the coordinator serves its GPU clients.
#[derive(Clone, Copy, Debug)]
pub enum ServeMode {
    /// One connection at a time, served to completion (the pre-batching
    /// baseline; kept for A/B throughput comparison).
    Sequential,
    /// One blocking reader thread per connection feeding the shared
    /// batcher (the pre-event-loop server; kept for A/B comparison).
    Threaded(BatchPolicy),
    /// Nonblocking event loop: a fixed poll-thread pool, incremental
    /// frame decode, admission control, cross-connection batching.
    Concurrent(BatchPolicy),
}

/// Serving counters, observable while the server runs (registry-backed
/// handles shared via [`CoordinatorServer::stats`]). `max_batch >= 2` is
/// the "batching actually happened" witness the integration tests assert
/// on.
///
/// Every counter lives in the server's telemetry [`Registry`] under a
/// stable dotted name (see `telemetry` module docs), so mid-run scrapes
/// see exactly what these getters see — the shutdown-time print is no
/// longer the only window. [`snapshot`](Self::snapshot) reads all of
/// them tear-free.
#[derive(Debug)]
pub struct ServerStats {
    requests: Arc<Counter>,
    rounds: Arc<Counter>,
    batches_ge2: Arc<Counter>,
    max_batch: Arc<Gauge>,
    teardowns: Arc<Counter>,
    accept_drops: Arc<Counter>,
    nodelay_fallbacks: Arc<Counter>,
    shed: Arc<Counter>,
    shutdown_denied: Arc<Counter>,
    deadline_shed: Arc<Counter>,
    partial: Arc<Counter>,
    received: Arc<Counter>,
    replies: Arc<Counter>,
    backpressure: Arc<Counter>,
    stats_denied: Arc<Counter>,
    /// Shed-reason split, indexed by `ShedReason::code() - 1`.
    shed_reasons: [Arc<Counter>; 3],
}

/// One tear-free copy of every serving counter: [`ServerStats::snapshot`]
/// re-reads until two consecutive passes agree, so related counters
/// (`received` vs `replies` vs `shed`) come from one consistent cut
/// instead of a field-by-field race.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub requests: u64,
    pub rounds: u64,
    pub batches_ge2: u64,
    pub max_batch: u64,
    pub teardowns: u64,
    pub accept_drops: u64,
    pub nodelay_fallbacks: u64,
    pub shed: u64,
    pub shutdown_denied: u64,
    pub deadline_shed: u64,
    pub partial: u64,
    pub received: u64,
    pub replies: u64,
    pub backpressure: u64,
    pub stats_denied: u64,
    pub shed_queue_full: u64,
    pub shed_rate_limited: u64,
    pub shed_deadline: u64,
}

impl ServerStats {
    /// Register the serving counters in `reg` under their stable names.
    pub fn new(reg: &Registry) -> ServerStats {
        ServerStats {
            requests: reg.counter("coordinator.requests"),
            rounds: reg.counter("coordinator.rounds"),
            batches_ge2: reg.counter("coordinator.batches_ge2"),
            max_batch: reg.gauge("coordinator.max_batch"),
            teardowns: reg.counter("coordinator.teardowns"),
            accept_drops: reg.counter("coordinator.accept_drops"),
            nodelay_fallbacks: reg.counter("coordinator.nodelay_fallbacks"),
            shed: reg.counter("coordinator.shed"),
            shutdown_denied: reg.counter("coordinator.shutdown_denied"),
            deadline_shed: reg.counter("coordinator.deadline_shed"),
            partial: reg.counter("coordinator.replies.partial"),
            received: reg.counter("coordinator.requests.received"),
            replies: reg.counter("coordinator.replies"),
            backpressure: reg.counter("coordinator.backpressure_frames"),
            stats_denied: reg.counter("coordinator.stats_denied"),
            shed_reasons: [
                reg.counter_with("coordinator.shed_reason", &[("reason", "queue_full")]),
                reg.counter_with("coordinator.shed_reason", &[("reason", "rate_limited")]),
                reg.counter_with(
                    "coordinator.shed_reason",
                    &[("reason", "deadline_expired")],
                ),
            ],
        }
    }

    fn record_round(&self, batch: u64) {
        self.requests.add(batch);
        self.rounds.inc();
        self.max_batch.set_max(batch);
        if batch >= 2 {
            self.batches_ge2.inc();
        }
    }

    /// Count one shed under its wire reason code (see
    /// [`ShedReason::code`]); unknown codes land on the deadline bucket
    /// (code 3 is the current max).
    fn record_shed_reason(&self, code: u32) {
        let idx = (code.clamp(1, 3) - 1) as usize;
        self.shed_reasons[idx].inc();
    }

    fn read_once(&self) -> StatsSnapshot {
        StatsSnapshot {
            requests: self.requests.get(),
            rounds: self.rounds.get(),
            batches_ge2: self.batches_ge2.get(),
            max_batch: self.max_batch.get(),
            teardowns: self.teardowns.get(),
            accept_drops: self.accept_drops.get(),
            nodelay_fallbacks: self.nodelay_fallbacks.get(),
            shed: self.shed.get(),
            shutdown_denied: self.shutdown_denied.get(),
            deadline_shed: self.deadline_shed.get(),
            partial: self.partial.get(),
            received: self.received.get(),
            replies: self.replies.get(),
            backpressure: self.backpressure.get(),
            stats_denied: self.stats_denied.get(),
            shed_queue_full: self.shed_reasons[0].get(),
            shed_rate_limited: self.shed_reasons[1].get(),
            shed_deadline: self.shed_reasons[2].get(),
        }
    }

    /// Tear-free snapshot: loop until two consecutive whole-struct reads
    /// agree (bounded retries; under a write storm the last read wins,
    /// which is still a point-in-time cut no worse than one pass).
    pub fn snapshot(&self) -> StatsSnapshot {
        let mut prev = self.read_once();
        for _ in 0..16 {
            let cur = self.read_once();
            if cur == prev {
                return cur;
            }
            prev = cur;
        }
        prev
    }

    /// Requests served.
    pub fn requests(&self) -> u64 {
        self.requests.get()
    }

    /// Dispatch rounds run (== requests in sequential mode).
    pub fn rounds(&self) -> u64 {
        self.rounds.get()
    }

    /// Rounds that carried at least two requests.
    pub fn batches_ge2(&self) -> u64 {
        self.batches_ge2.get()
    }

    /// Largest dispatched batch.
    pub fn max_batch(&self) -> u64 {
        self.max_batch.get()
    }

    /// Connection teardowns processed (speculation-slot hygiene ran).
    pub fn teardowns(&self) -> u64 {
        self.teardowns.get()
    }

    /// Connections dropped at accept because their socket could not be
    /// set up (e.g. `try_clone` failed) — closed explicitly, not leaked.
    pub fn accept_drops(&self) -> u64 {
        self.accept_drops.get()
    }

    /// Connections served *without* TCP_NODELAY because setting it
    /// failed (previously such connections were silently dropped).
    pub fn nodelay_fallbacks(&self) -> u64 {
        self.nodelay_fallbacks.get()
    }

    /// Requests refused by admission control (a `Backpressure` frame was
    /// sent instead of a retrieval reply).
    pub fn shed(&self) -> u64 {
        self.shed.get()
    }

    /// `Shutdown` frames ignored because they came from a non-admin
    /// connection.
    pub fn shutdown_denied(&self) -> u64 {
        self.shutdown_denied.get()
    }

    /// Requests shed because their end-to-end deadline expired while
    /// they waited in the server queue (a subset of [`shed`](Self::shed)).
    pub fn deadline_shed(&self) -> u64 {
        self.deadline_shed.get()
    }

    /// Replies served with coverage below 1.0 (degraded partial results).
    pub fn partial(&self) -> u64 {
        self.partial.get()
    }

    /// Well-formed `RetrieveRequest`s decoded (admitted or shed).
    pub fn received(&self) -> u64 {
        self.received.get()
    }

    /// Retrieval replies written (complete + partial). Conservation:
    /// `received == replies + shed + in-flight` at any instant, with
    /// in-flight = 0 once the server quiesces.
    pub fn replies(&self) -> u64 {
        self.replies.get()
    }

    /// `Backpressure` frames produced (== [`shed`](Self::shed) — pinned
    /// by the CI scrape check).
    pub fn backpressure_frames(&self) -> u64 {
        self.backpressure.get()
    }

    /// `StatsRequest` frames refused by the admin gate.
    pub fn stats_denied(&self) -> u64 {
        self.stats_denied.get()
    }
}

impl Default for ServerStats {
    /// Stand-alone stats backed by a private registry (tests construct
    /// these; servers use [`ServerStats::new`] with their telemetry
    /// registry so scrapes see the counters).
    fn default() -> Self {
        ServerStats::new(&Registry::default())
    }
}

/// One decoded request waiting in the shared batcher.
struct ServerRequest {
    conn_id: u64,
    query_id: u64,
    gpu_id: u32,
    want_chunks: bool,
    query: Vec<f32>,
    /// End-to-end trace id (0 = untraced).
    trace_id: u64,
    /// When the reader decoded the request — start of the queue-wait
    /// span and of the end-to-end total.
    arrived: Instant,
    /// Absolute end-to-end deadline (from the request's `deadline_us`
    /// budget, anchored at arrival); `None` = unbounded legacy request.
    deadline: Option<Instant>,
}

/// State shared between the accept thread, the readers (poll pool or
/// per-connection threads) and the dispatch loop.
struct Shared {
    batcher: Mutex<ClassedBatcher<ServerRequest>>,
    /// Per-tenant admission state (bounded queues + token buckets).
    admission: Mutex<Admission>,
    qos: QosConfig,
    /// Woken on request arrival, teardown, cluster transition and stop.
    cv: Condvar,
    /// Connections whose reader exited; the dispatch loop cancels their
    /// speculation slots (it owns the retriever).
    teardowns: Mutex<Vec<u64>>,
    /// Pending cluster-membership transitions, applied by the dispatch
    /// loop *between* batches (it owns the retriever, so epochs swap
    /// without dropping in-flight requests).
    cluster_ops: Mutex<Vec<(u64, ClusterUpdate)>>,
    /// Reply routes: connection id -> writer half. All frame writes to a
    /// connection happen under this lock, so admission-time
    /// `Backpressure` frames never interleave bytes with batch replies.
    writers: Mutex<HashMap<u64, TcpStream>>,
    /// Freshly accepted nonblocking connections awaiting adoption by
    /// their poll thread (event-loop mode only).
    injected: Mutex<Vec<(u64, TcpStream)>>,
    stop: AtomicBool,
    stats: Arc<ServerStats>,
    /// The live telemetry plane: metrics registry, per-tenant SLO burn
    /// tracking, tail sampler. `Telemetry::off()` short-circuits every
    /// observation (the A/B baseline); see
    /// [`CoordinatorServer::spawn_telemetry`].
    telemetry: Arc<Telemetry>,
    /// Span sink shared by the readers (trace-id allocation) and the
    /// dispatch loop (queue-wait/reply-write/total spans). Off by
    /// default; see [`CoordinatorServer::spawn_traced`].
    tracer: Tracer,
    /// Trace-id allocator (0 is reserved for "untraced").
    next_trace: AtomicU64,
}

impl Shared {
    /// A fresh trace id — or 0 when tracing is off, so the untraced hot
    /// path records nothing.
    fn alloc_trace(&self) -> u64 {
        if self.tracer.enabled() {
            self.next_trace.fetch_add(1, Ordering::Relaxed)
        } else {
            0
        }
    }
}

/// A running coordinator server.
pub struct CoordinatorServer {
    pub addr: SocketAddr,
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl CoordinatorServer {
    /// Spawn the concurrent coordinator with the default batch policy.
    /// The retriever is built on the dispatch thread (PJRT engines are
    /// not Send).
    pub fn spawn_with(
        builder: impl FnOnce() -> Retriever + Send + 'static,
    ) -> Result<CoordinatorServer> {
        Self::spawn(builder, ServeMode::Concurrent(BatchPolicy::default()))
    }

    /// Spawn the one-connection-at-a-time baseline server.
    pub fn spawn_sequential(
        builder: impl FnOnce() -> Retriever + Send + 'static,
    ) -> Result<CoordinatorServer> {
        Self::spawn(builder, ServeMode::Sequential)
    }

    /// Spawn the coordinator on an ephemeral local port in the given mode.
    pub fn spawn(
        builder: impl FnOnce() -> Retriever + Send + 'static,
        mode: ServeMode,
    ) -> Result<CoordinatorServer> {
        Self::spawn_traced(builder, mode, Tracer::off())
    }

    /// [`spawn`](Self::spawn) with a span sink: every request gets a
    /// fresh trace id, and its `queue_wait`, retrieval-stage,
    /// `reply_write` and `total` spans land in the tracer's ring for
    /// offline aggregation (`chameleon report trace`).
    pub fn spawn_traced(
        builder: impl FnOnce() -> Retriever + Send + 'static,
        mode: ServeMode,
        tracer: Tracer,
    ) -> Result<CoordinatorServer> {
        Self::spawn_qos(builder, mode, QosConfig::default(), tracer)
    }

    /// Fully explicit spawn: serving mode, QoS/admission configuration
    /// and span sink. The default [`QosConfig`] is deliberately generous
    /// (single-tenant workloads never shed); multi-tenant deployments
    /// tighten the per-class policies here.
    pub fn spawn_qos(
        builder: impl FnOnce() -> Retriever + Send + 'static,
        mode: ServeMode,
        qos: QosConfig,
        tracer: Tracer,
    ) -> Result<CoordinatorServer> {
        let telemetry = Telemetry::new(TelemetryConfig {
            slo_interactive: qos.slo_interactive,
            slo_batch: qos.slo_batch,
            ..TelemetryConfig::default()
        });
        Self::spawn_telemetry(builder, mode, qos, tracer, telemetry)
    }

    /// [`spawn_qos`](Self::spawn_qos) with an explicit telemetry plane.
    /// Pass [`Telemetry::off`] to measure the plane's overhead (the
    /// serving counters keep working either way — they are plain
    /// registry handles); anything else makes every counter, per-tenant
    /// histogram, burn rate and tail sample live-scrapeable via
    /// `StatsRequest` frames or a [`crate::telemetry::MetricsServer`].
    pub fn spawn_telemetry(
        builder: impl FnOnce() -> Retriever + Send + 'static,
        mode: ServeMode,
        qos: QosConfig,
        tracer: Tracer,
        telemetry: Arc<Telemetry>,
    ) -> Result<CoordinatorServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let policy = match mode {
            ServeMode::Sequential => BatchPolicy::default(),
            ServeMode::Threaded(p) | ServeMode::Concurrent(p) => p,
        };
        let shared = Arc::new(Shared {
            batcher: Mutex::new(ClassedBatcher::new(policy)),
            admission: Mutex::new(Admission::new(qos)),
            qos,
            cv: Condvar::new(),
            teardowns: Mutex::new(Vec::new()),
            cluster_ops: Mutex::new(Vec::new()),
            writers: Mutex::new(HashMap::new()),
            injected: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            stats: Arc::new(ServerStats::new(telemetry.registry())),
            telemetry,
            tracer,
            next_trace: AtomicU64::new(1),
        });
        let mut handles = Vec::new();
        match mode {
            ServeMode::Sequential => {
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    serve_sequential(listener, builder, &sh);
                }));
            }
            ServeMode::Threaded(_) => {
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    dispatch_loop(builder, &sh);
                }));
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    accept_loop(listener, addr, &sh, false);
                }));
            }
            ServeMode::Concurrent(_) => {
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    dispatch_loop(builder, &sh);
                }));
                let pool = qos.poll_threads.max(1);
                for tid in 0..pool {
                    let sh = shared.clone();
                    handles.push(std::thread::spawn(move || {
                        poll_loop(tid, pool, addr, &sh);
                    }));
                }
                let sh = shared.clone();
                handles.push(std::thread::spawn(move || {
                    accept_loop(listener, addr, &sh, true);
                }));
            }
        }
        Ok(CoordinatorServer { addr, shared, handles })
    }

    /// Live serving counters (shared handle; stays valid after shutdown).
    pub fn stats(&self) -> Arc<ServerStats> {
        self.shared.stats.clone()
    }

    /// The server's telemetry plane (registry + SLO tracking + tail
    /// sampler). Hand it to a [`crate::telemetry::MetricsServer`] to
    /// expose a Prometheus-text scrape endpoint alongside the protocol's
    /// `StatsRequest` path.
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.shared.telemetry.clone()
    }

    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        // Nudge the accept loop out of its blocking accept.
        let _ = TcpStream::connect(self.addr);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for CoordinatorServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Write one frame with a hard time bound, riding out `WouldBlock` on
/// nonblocking sockets by waiting for write readiness. Used for every
/// reply write in the threaded/concurrent servers: in event-loop mode
/// the registered writer halves share their file description with the
/// nonblocking read side, so a plain `write_all` could fail spuriously
/// on a congested peer.
fn write_frame_bounded(
    stream: &mut TcpStream,
    frame: &Frame,
    limit: Duration,
) -> std::io::Result<()> {
    let bytes = frame.to_bytes();
    let deadline = Instant::now() + limit;
    let mut off = 0;
    while off < bytes.len() {
        match stream.write(&bytes[off..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "peer stopped accepting bytes",
                ))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                let now = Instant::now();
                if now >= deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "reply write exceeded its time bound",
                    ));
                }
                let wait = (deadline - now).min(Duration::from_millis(50));
                wait_writable(raw_fd(stream), wait);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Convert a request's relative `deadline_us` budget into the absolute
/// deadline every downstream stage (queue, dispatch, scan, retry, hedge)
/// draws from. 0 = no deadline (legacy clients).
fn deadline_from_us(arrived: Instant, deadline_us: u64) -> Option<Instant> {
    if deadline_us == 0 {
        None
    } else {
        Some(arrived + Duration::from_micros(deadline_us))
    }
}

// ------------------------------------------------------- sequential mode

fn serve_sequential(
    listener: TcpListener,
    builder: impl FnOnce() -> Retriever,
    shared: &Shared,
) {
    let mut retriever = builder();
    retriever.set_tracer(shared.tracer.clone());
    let metrics = Metrics::new();
    let mut prefetch = PrefetchTracker::new();
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        match conn {
            Ok(stream) => {
                let _ = serve_gpu(stream, &mut retriever, &metrics, &mut prefetch, shared);
                // Connection teardown: cancel exactly the slots this
                // connection's GPU sources touched, so a departed
                // client's predictions never verify against whoever
                // connects next (other connections' lanes untouched).
                for &slot in prefetch.sources() {
                    retriever.cancel_slot_speculation(slot);
                }
                prefetch.reset();
                shared.stats.teardowns.inc();
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    if retriever.retcache_enabled() {
        retriever.export_metrics(&metrics);
    }
    if let Some(c) = retriever.dispatcher.cluster() {
        eprintln!("[coordinator] cluster: epoch={} {}", c.epoch(), c.stats().render());
    }
    eprintln!("[coordinator] metrics:\n{}", metrics.render());
}

fn serve_gpu(
    mut stream: TcpStream,
    retriever: &mut Retriever,
    metrics: &Metrics,
    prefetch: &mut PrefetchTracker,
    shared: &Shared,
) -> Result<()> {
    if stream.set_nodelay(true).is_err() {
        shared.stats.nodelay_fallbacks.inc();
    }
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    // Incremental decode: a read timeout mid-frame keeps the partial
    // bytes buffered instead of restarting the parse mid-stream.
    let mut frames = FrameReader::new();
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match frames.poll(&mut stream) {
            Ok(ReadProgress::Frame(f)) => f,
            Ok(ReadProgress::Idle) => continue,
            Ok(ReadProgress::Closed) | Err(_) => return Ok(()),
        };
        match frame.kind {
            Kind::Shutdown => {
                shared.stop.store(true, Ordering::Relaxed);
                return Ok(());
            }
            Kind::RetrieveRequest => {
                let req = RetrieveRequest::decode(&frame)?;
                anyhow::ensure!(
                    req.query.len() == retriever.dim(),
                    "query dim {} != index dim {}",
                    req.query.len(),
                    retriever.dim()
                );
                metrics.incr("retrieve_requests", 1);
                metrics.incr(&format!("gpu_{}_requests", req.gpu_id), 1);
                shared.stats.received.inc();
                shared.stats.record_round(1);
                // Retcache path: each GPU source owns its own speculation
                // slot, so interleaved sources no longer cancel each
                // other's prefetches — the switch rate is kept as an
                // interleaving metric only.
                let slot = req.gpu_id as usize;
                if prefetch.observe(slot) {
                    metrics.incr("retcache.prefetch_source_switches", 1);
                }
                let arrived = Instant::now();
                let trace_id = shared.alloc_trace();
                let opts = RoundOptions {
                    degraded: shared.qos.degraded,
                    deadline: deadline_from_us(arrived, req.deadline_us),
                };
                let r = if retriever.retcache_enabled() {
                    let cr = metrics.time("retrieve", || {
                        retriever.retrieve_cached_opts(
                            slot,
                            Some(req.gpu_id),
                            &req.query,
                            trace_id,
                            &opts,
                        )
                    })?;
                    metrics.incr(source_counter(cr.source), 1);
                    cr.result
                } else {
                    metrics.time("retrieve", || {
                        retriever.retrieve_with(&req.query, trace_id, &opts)
                    })?
                };
                let tokens = if req.want_chunks {
                    retriever.gather_chunks(&r.ids)
                } else {
                    retriever.gather_next_tokens(&r.ids)
                };
                let partial = r.is_partial();
                if partial {
                    shared.stats.partial.inc();
                }
                let resp = RetrieveResponse {
                    query_id: req.query_id,
                    tokens,
                    dists: r.dists,
                    shards_answered: r.shards_answered,
                    n_shards: r.n_shards,
                };
                let t_write = Instant::now();
                resp.encode().write_to(&mut writer)?;
                shared.stats.replies.inc();
                shared.telemetry.observe(
                    req.gpu_id,
                    arrived.elapsed().as_micros() as u64,
                    if partial { Outcome::Partial } else { Outcome::Complete },
                    trace_id,
                );
                if trace_id != 0 {
                    // Sequential mode has no batching queue: the request
                    // is served the moment it is decoded.
                    shared.tracer.record(trace_id, SpanKind::QueueWait, 0, 0.0);
                    shared.tracer.record(
                        trace_id,
                        SpanKind::ReplyWrite,
                        0,
                        t_write.elapsed().as_secs_f64(),
                    );
                    shared.tracer.record(
                        trace_id,
                        SpanKind::Total,
                        0,
                        arrived.elapsed().as_secs_f64(),
                    );
                }
            }
            Kind::ClusterUpdate => {
                let update = ClusterUpdate::decode(&frame)?;
                // Sequential mode serves one connection at a time, so
                // "between batches" is simply "right now".
                let ack = apply_cluster_update(retriever, &update);
                ack.encode().write_to(&mut writer)?;
            }
            Kind::StatsRequest => {
                let req = StatsRequest::decode(&frame)?;
                // Refresh the pull-model gauges (cluster, retcache,
                // admission depths) so the scrape sees the live values.
                export_side_stats(retriever, shared);
                // Sequential mode serves one connection at a time; it is
                // by definition the first (admin) connection.
                let resp = stats_response_frame(0, &req, shared);
                resp.write_to(&mut writer)?;
            }
            other => anyhow::bail!("unexpected frame {other:?} at coordinator"),
        }
    }
}

// --------------------------------------------- concurrent + threaded mode

/// Accept connections and register their writer halves. In event-loop
/// mode (`event_loop`) the connection is made nonblocking and handed to
/// its poll thread via the injection queue; in threaded mode a blocking
/// reader thread is spawned per connection. Socket-setup failures close
/// the connection explicitly and are counted — never silently leaked.
fn accept_loop(listener: TcpListener, addr: SocketAddr, shared: &Arc<Shared>, event_loop: bool) {
    let mut next_conn = 0u64;
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        match conn {
            Ok(stream) => {
                // Best effort: a connection that can't get TCP_NODELAY is
                // served anyway (it only costs latency), and counted.
                if stream.set_nodelay(true).is_err() {
                    shared.stats.nodelay_fallbacks.inc();
                }
                let writer = match stream.try_clone() {
                    Ok(w) => w,
                    Err(_) => {
                        // Can't build a reply route: close the socket
                        // explicitly (dropping it here) so the peer sees
                        // a reset instead of a half-open black hole.
                        shared.stats.accept_drops.inc();
                        continue;
                    }
                };
                if event_loop && stream.set_nonblocking(true).is_err() {
                    shared.stats.accept_drops.inc();
                    continue;
                }
                let conn_id = next_conn;
                next_conn += 1;
                shared.writers.lock().unwrap().insert(conn_id, writer);
                if event_loop {
                    shared.injected.lock().unwrap().push((conn_id, stream));
                } else {
                    let sh = shared.clone();
                    // Readers are detached: they exit on disconnect or
                    // within one poll interval of the stop flag.
                    std::thread::spawn(move || reader_loop(stream, conn_id, addr, &sh));
                }
            }
            Err(_) => break,
        }
    }
}

/// What to do with a connection after handling one of its frames.
enum FrameOutcome {
    /// Keep reading.
    Continue,
    /// Protocol error or dead reply route: drop the connection.
    Close,
    /// Server shutdown was accepted; stop flag is already set.
    Stop,
}

/// Handle one decoded frame from connection `conn_id` — shared by the
/// event loop's poll threads and the threaded mode's reader threads.
/// Replies (`Backpressure` here, retrieval replies in the dispatch loop)
/// go through the registered writer under the `writers` lock, which
/// serializes all frame writes to a connection.
fn handle_frame(conn_id: u64, frame: &Frame, addr: SocketAddr, shared: &Shared) -> FrameOutcome {
    match frame.kind {
        Kind::Shutdown => {
            // Only the admin connection (the first accepted) may stop the
            // server for everyone; other tenants' shutdowns are counted
            // and ignored.
            if shared.qos.admin_shutdown_only && conn_id != 0 {
                shared.stats.shutdown_denied.inc();
                return FrameOutcome::Continue;
            }
            shared.stop.store(true, Ordering::Relaxed);
            shared.cv.notify_all();
            // Nudge the accept loop so it observes the stop flag.
            let _ = TcpStream::connect(addr);
            FrameOutcome::Stop
        }
        Kind::RetrieveRequest => match RetrieveRequest::decode(frame) {
            Ok(req) => {
                let tenant = req.gpu_id;
                shared.stats.received.inc();
                let verdict = shared.admission.lock().unwrap().admit(tenant, Instant::now());
                match verdict {
                    Ok(()) => {
                        let trace_id = shared.alloc_trace();
                        let arrived = Instant::now();
                        let mut b = shared.batcher.lock().unwrap();
                        b.push(
                            QosClass::of_gpu(tenant),
                            tenant as usize,
                            ServerRequest {
                                conn_id,
                                query_id: req.query_id,
                                gpu_id: tenant,
                                want_chunks: req.want_chunks,
                                query: req.query,
                                trace_id,
                                arrived,
                                deadline: deadline_from_us(arrived, req.deadline_us),
                            },
                        );
                        drop(b);
                        shared.cv.notify_all();
                    }
                    Err(shed) => {
                        // Shed: tell the client explicitly instead of
                        // queueing unboundedly or going silent. Written
                        // at admission time, so it can overtake earlier
                        // retrieval replies — clients match by query_id.
                        shared.stats.shed.inc();
                        shared.stats.record_shed_reason(shed.reason.code());
                        shared.telemetry.observe(tenant, 0, Outcome::Shed, 0);
                        let bp = Backpressure {
                            query_id: req.query_id,
                            tenant,
                            reason: shed.reason.code(),
                            queue_depth: shed.queue_depth,
                            retry_after_us: shed.retry_after_us,
                        };
                        let mut writers = shared.writers.lock().unwrap();
                        if let Some(stream) = writers.get_mut(&conn_id) {
                            // Counted adjacent to the write so the
                            // scrape-side invariant `sheds ==
                            // backpressure_frames` holds exactly.
                            shared.stats.backpressure.inc();
                            if write_frame_bounded(stream, &bp.encode(), WRITE_LIMIT).is_err() {
                                let _ = stream.shutdown(std::net::Shutdown::Both);
                                writers.remove(&conn_id);
                                return FrameOutcome::Close;
                            }
                        }
                    }
                }
                FrameOutcome::Continue
            }
            Err(_) => FrameOutcome::Close,
        },
        Kind::ClusterUpdate => match ClusterUpdate::decode(frame) {
            Ok(update) => {
                shared.cluster_ops.lock().unwrap().push((conn_id, update));
                shared.cv.notify_all();
                FrameOutcome::Continue
            }
            Err(_) => FrameOutcome::Close,
        },
        Kind::StatsRequest => match StatsRequest::decode(frame) {
            Ok(req) => {
                // Served inline on the reader/poll thread: the snapshot
                // only reads registry handles, never the retriever, so a
                // scrape cannot stall the dispatch loop. Cluster/retcache
                // gauges are as fresh as the last served batch.
                let resp = stats_response_frame(conn_id, &req, shared);
                let mut writers = shared.writers.lock().unwrap();
                if let Some(stream) = writers.get_mut(&conn_id) {
                    if write_frame_bounded(stream, &resp, WRITE_LIMIT).is_err() {
                        let _ = stream.shutdown(std::net::Shutdown::Both);
                        writers.remove(&conn_id);
                        return FrameOutcome::Close;
                    }
                }
                FrameOutcome::Continue
            }
            Err(_) => FrameOutcome::Close,
        },
        _ => FrameOutcome::Close,
    }
}

/// Deregister a connection and queue its speculation-slot teardown for
/// the dispatch loop.
fn retire_conn(conn_id: u64, shared: &Shared) {
    shared.writers.lock().unwrap().remove(&conn_id);
    shared.teardowns.lock().unwrap().push(conn_id);
    shared.cv.notify_all();
}

/// Threaded mode: decode one connection's frames into the shared batcher
/// on a dedicated blocking thread. On exit (peer closed, protocol error,
/// or server stop) the connection is deregistered and queued for
/// speculation-slot teardown on the dispatch loop.
fn reader_loop(mut stream: TcpStream, conn_id: u64, addr: SocketAddr, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut frames = FrameReader::new();
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        match frames.poll(&mut stream) {
            Ok(ReadProgress::Frame(frame)) => {
                match handle_frame(conn_id, &frame, addr, shared) {
                    FrameOutcome::Continue => {}
                    FrameOutcome::Close | FrameOutcome::Stop => break,
                }
            }
            // Read timeout: only idleness — any partial frame stays
            // buffered in the FrameReader (the old per-frame decode
            // restarted parsing here and desynced on slow clients).
            Ok(ReadProgress::Idle) => continue,
            Ok(ReadProgress::Closed) | Err(_) => break,
        }
    }
    retire_conn(conn_id, shared);
}

/// One poll thread of the event loop: owns every connection with
/// `conn_id % pool == tid`, waits for read readiness across all of them
/// at once, and pumps each ready connection's [`FrameReader`] until it
/// goes idle. Per-connection state is one `FrameReader` (at most one
/// frame buffered) — no thread, no stack, regardless of client count.
fn poll_loop(tid: usize, pool: usize, addr: SocketAddr, shared: &Arc<Shared>) {
    struct Conn {
        id: u64,
        stream: TcpStream,
        frames: FrameReader,
    }
    let mut conns: Vec<Conn> = Vec::new();
    while !shared.stop.load(Ordering::Relaxed) {
        // Adopt freshly accepted connections sharded to this thread.
        {
            let mut inj = shared.injected.lock().unwrap();
            let mut i = 0;
            while i < inj.len() {
                if (inj[i].0 as usize) % pool == tid {
                    let (id, stream) = inj.remove(i);
                    conns.push(Conn { id, stream, frames: FrameReader::new() });
                } else {
                    i += 1;
                }
            }
        }
        // Readiness over the whole shard; with no connections this just
        // parks for one tick.
        let fds: Vec<i32> = conns.iter().map(|c| raw_fd(&c.stream)).collect();
        let ready = wait_readable(&fds, EVENT_POLL);
        let mut dead: Vec<usize> = Vec::new();
        for (i, c) in conns.iter_mut().enumerate() {
            if !ready[i] {
                continue;
            }
            // Pump until the socket runs dry: nonblocking reads hit
            // `WouldBlock` (-> Idle) when the kernel buffer empties,
            // with any partial frame held in the FrameReader.
            loop {
                match c.frames.poll(&mut c.stream) {
                    Ok(ReadProgress::Frame(frame)) => {
                        match handle_frame(c.id, &frame, addr, shared) {
                            FrameOutcome::Continue => {}
                            FrameOutcome::Close | FrameOutcome::Stop => {
                                dead.push(i);
                                break;
                            }
                        }
                    }
                    Ok(ReadProgress::Idle) => break,
                    Ok(ReadProgress::Closed) | Err(_) => {
                        dead.push(i);
                        break;
                    }
                }
            }
        }
        // Indices were pushed in ascending order; remove back to front.
        for &i in dead.iter().rev() {
            let c = conns.remove(i);
            retire_conn(c.id, shared);
        }
    }
}

/// What the dispatch loop should do next.
enum Step {
    /// Serve this drained batch.
    Batch(Vec<Pending<ServerRequest>>),
    /// Process pending connection teardowns first.
    Teardown,
    /// Apply pending cluster-membership transitions (between batches).
    Cluster,
    /// Stop flag set and the queue fully drained.
    Stop,
}

/// Block until the batch policy fires, a teardown or cluster transition
/// is pending, or the server stops (draining any queued requests first).
fn next_step(shared: &Shared) -> Step {
    let mut guard = shared.batcher.lock().unwrap();
    loop {
        if !shared.teardowns.lock().unwrap().is_empty() {
            return Step::Teardown;
        }
        if !shared.cluster_ops.lock().unwrap().is_empty() {
            return Step::Cluster;
        }
        let now = Instant::now();
        if guard.ready(now) {
            return Step::Batch(guard.take_batch());
        }
        if shared.stop.load(Ordering::Relaxed) {
            return if guard.is_empty() {
                Step::Stop
            } else {
                Step::Batch(guard.take_batch())
            };
        }
        let wait = guard.time_to_ready(now).unwrap_or(POLL).min(POLL);
        let (g, _) = shared.cv.wait_timeout(guard, wait).unwrap();
        guard = g;
    }
}

/// The coordinator's serving core: owns the retriever, drains
/// cross-connection batches, and routes replies back by connection id.
fn dispatch_loop(builder: impl FnOnce() -> Retriever, shared: &Shared) {
    let mut retriever = builder();
    retriever.set_tracer(shared.tracer.clone());
    let metrics = Metrics::new();
    // Per-connection source tracking (slot hygiene + interleave metric).
    let mut trackers: HashMap<u64, PrefetchTracker> = HashMap::new();
    loop {
        match next_step(shared) {
            Step::Stop => break,
            Step::Cluster => {
                // Membership transitions apply strictly between batches:
                // the epoch the next round sees is fully swapped, and no
                // queued request is dropped (it just dispatches under the
                // new epoch).
                let ops: Vec<(u64, ClusterUpdate)> =
                    std::mem::take(&mut *shared.cluster_ops.lock().unwrap());
                for (conn_id, update) in ops {
                    let ack = apply_cluster_update(&mut retriever, &update);
                    let mut writers = shared.writers.lock().unwrap();
                    if let Some(stream) = writers.get_mut(&conn_id) {
                        if write_frame_bounded(stream, &ack.encode(), WRITE_LIMIT).is_err() {
                            let _ = stream.shutdown(std::net::Shutdown::Both);
                            writers.remove(&conn_id);
                        }
                    }
                }
            }
            Step::Teardown => {
                let dead: Vec<u64> = std::mem::take(&mut *shared.teardowns.lock().unwrap());
                for conn_id in dead {
                    // Cancel exactly the slots this connection's GPU
                    // sources touched — unless a still-live connection
                    // (e.g. the same GPU reconnected) has since claimed
                    // the slot, in which case its lane stays untouched.
                    if let Some(t) = trackers.remove(&conn_id) {
                        for &slot in t.sources() {
                            let claimed_by_live = trackers
                                .values()
                                .any(|o| o.sources().contains(&slot));
                            if !claimed_by_live {
                                retriever.cancel_slot_speculation(slot);
                            }
                        }
                    }
                    shared.stats.teardowns.inc();
                }
            }
            Step::Batch(batch) => {
                if batch.is_empty() {
                    continue;
                }
                serve_batch(&batch, &mut retriever, &metrics, shared, &mut trackers);
                // Refresh the pull-model gauges (cluster round counters,
                // retcache hit rates, admission queue depths) after every
                // served batch so a mid-run scrape is at most one batch
                // stale.
                export_side_stats(&retriever, shared);
            }
        }
    }
    export_side_stats(&retriever, shared);
    if retriever.retcache_enabled() {
        retriever.export_metrics(&metrics);
    }
    if let Some(c) = retriever.dispatcher.cluster() {
        eprintln!("[coordinator] cluster: epoch={} {}", c.epoch(), c.stats().render());
    }
    eprintln!("[coordinator] metrics:\n{}", metrics.render());
}

/// Serve one drained batch: retrieval (one parallel dispatcher round when
/// retcache is off; the cache/speculation-aware per-request path when it
/// is on), token conversion, and reply routing.
fn serve_batch(
    batch: &[Pending<ServerRequest>],
    retriever: &mut Retriever,
    metrics: &Metrics,
    shared: &Shared,
    trackers: &mut HashMap<u64, PrefetchTracker>,
) {
    // Every drained request leaves its bounded tenant queue *now* — even
    // one whose connection died below — so admission's queued-count
    // matches reality and a tenant's cap frees up as its work drains.
    {
        let mut adm = shared.admission.lock().unwrap();
        for p in batch {
            adm.release(p.payload.gpu_id);
        }
    }
    // Drop requests whose connection is already gone (reader exited): they
    // have no reply route, and serving them would resurrect a tracker —
    // and possibly launch speculation on a slot — *after* that
    // connection's teardown already ran.
    let batch: Vec<&Pending<ServerRequest>> = {
        let writers = shared.writers.lock().unwrap();
        batch
            .iter()
            .filter(|p| writers.contains_key(&p.payload.conn_id))
            .collect()
    };
    // Shed requests whose end-to-end budget expired while they queued:
    // running the round would spend cluster work on answers the clients
    // have already written off. The client gets an explicit
    // `Backpressure` verdict (reason `DeadlineExpired`), not silence.
    let now = Instant::now();
    let (batch, expired): (Vec<_>, Vec<_>) = batch
        .into_iter()
        .partition(|p| p.payload.deadline.map_or(true, |dl| now < dl));
    for p in expired {
        shared.stats.shed.inc();
        shared.stats.deadline_shed.inc();
        shared.stats.record_shed_reason(ShedReason::DeadlineExpired.code());
        shared.telemetry.observe(
            p.payload.gpu_id,
            p.payload.arrived.elapsed().as_micros() as u64,
            Outcome::Shed,
            p.payload.trace_id,
        );
        let bp = Backpressure {
            query_id: p.payload.query_id,
            tenant: p.payload.gpu_id,
            reason: ShedReason::DeadlineExpired.code(),
            queue_depth: 0,
            // The budget is gone; retrying this request is pointless.
            retry_after_us: 0,
        };
        let mut writers = shared.writers.lock().unwrap();
        if let Some(stream) = writers.get_mut(&p.payload.conn_id) {
            // Adjacent to the frame write: sheds with a live reply route
            // always produce exactly one Backpressure frame.
            shared.stats.backpressure.inc();
            if write_frame_bounded(stream, &bp.encode(), WRITE_LIMIT).is_err() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
                writers.remove(&p.payload.conn_id);
            }
        }
    }
    if batch.is_empty() {
        return;
    }
    shared.stats.record_round(batch.len() as u64);
    metrics.incr("retrieve_requests", batch.len() as u64);
    for p in &batch {
        metrics.incr(&format!("gpu_{}_requests", p.payload.gpu_id), 1);
        let tracker = trackers.entry(p.payload.conn_id).or_default();
        if tracker.observe(p.payload.gpu_id as usize) {
            metrics.incr("retcache.prefetch_source_switches", 1);
        }
        // Queue wait: reader decode -> batch drain (the batching delay
        // plus any backlog behind earlier rounds).
        if p.payload.trace_id != 0 {
            shared.tracer.record(
                p.payload.trace_id,
                SpanKind::QueueWait,
                0,
                p.payload.arrived.elapsed().as_secs_f64(),
            );
        }
    }
    // A malformed query (wrong dimensionality) must fail only its own
    // connection — never the shared round the other clients are riding.
    let dim = retriever.dim();
    let bad_dim = |p: &Pending<ServerRequest>| {
        anyhow::anyhow!("query dim {} != index dim {dim}", p.payload.query.len())
    };
    let results: Vec<Result<RetrievalResult>> = if retriever.retcache_enabled() {
        // The cache-aware path is per-request (hits skip the round trip
        // entirely); requests still arrived and reply in batch order.
        // Each tenant probes its own slice of the cache byte budget, so
        // one tenant's churn cannot evict another tenant's entries.
        batch
            .iter()
            .map(|p| {
                if p.payload.query.len() != dim {
                    return Err(bad_dim(p));
                }
                let slot = p.payload.gpu_id as usize;
                let opts = RoundOptions {
                    degraded: shared.qos.degraded,
                    deadline: p.payload.deadline,
                };
                metrics
                    .time("retrieve", || {
                        retriever.retrieve_cached_opts(
                            slot,
                            Some(p.payload.gpu_id),
                            &p.payload.query,
                            p.payload.trace_id,
                            &opts,
                        )
                    })
                    .map(|cr| {
                        metrics.incr(source_counter(cr.source), 1);
                        cr.result
                    })
            })
            .collect()
    } else {
        // The whole cross-connection batch in ONE parallel dispatch round
        // (per-node work queues; one round trip per remote node),
        // restricted to the well-formed queries.
        let mut results: Vec<Result<RetrievalResult>> =
            batch.iter().map(|p| Err(bad_dim(p))).collect();
        let valid: Vec<usize> = (0..batch.len())
            .filter(|&i| batch[i].payload.query.len() == dim)
            .collect();
        let refs: Vec<&[f32]> = valid
            .iter()
            .map(|&i| batch[i].payload.query.as_slice())
            .collect();
        let trace_ids: Vec<u64> =
            valid.iter().map(|&i| batch[i].payload.trace_id).collect();
        if !refs.is_empty() {
            // The whole round shares one deadline: the tightest budget in
            // the batch (requests ride a shared fan-out, so the round can
            // only be as patient as its most impatient member).
            let opts = RoundOptions {
                degraded: shared.qos.degraded,
                deadline: valid
                    .iter()
                    .filter_map(|&i| batch[i].payload.deadline)
                    .min(),
            };
            match metrics
                .time("retrieve", || retriever.retrieve_many_with(&refs, &trace_ids, &opts))
            {
                Ok(rs) => {
                    for (&i, r) in valid.iter().zip(rs) {
                        results[i] = Ok(r);
                    }
                }
                Err(e) => {
                    eprintln!("[coordinator] batch retrieval failed: {e:#}");
                    for &i in &valid {
                        results[i] = Err(anyhow::anyhow!("batch retrieval failed"));
                    }
                }
            }
        }
        results
    };
    for (p, result) in batch.iter().zip(results) {
        match result {
            Ok(r) => {
                let tokens = if p.payload.want_chunks {
                    retriever.gather_chunks(&r.ids)
                } else {
                    retriever.gather_next_tokens(&r.ids)
                };
                let partial = r.is_partial();
                if partial {
                    shared.stats.partial.inc();
                }
                let resp = RetrieveResponse {
                    query_id: p.payload.query_id,
                    tokens,
                    dists: r.dists,
                    shards_answered: r.shards_answered,
                    n_shards: r.n_shards,
                };
                let t_write = Instant::now();
                let mut writers = shared.writers.lock().unwrap();
                if let Some(stream) = writers.get_mut(&p.payload.conn_id) {
                    if write_frame_bounded(stream, &resp.encode(), WRITE_LIMIT).is_err() {
                        // Dead peer: drop the route; the reader side will
                        // queue the teardown.
                        let _ = stream.shutdown(std::net::Shutdown::Both);
                        writers.remove(&p.payload.conn_id);
                    }
                }
                drop(writers);
                shared.stats.replies.inc();
                shared.telemetry.observe(
                    p.payload.gpu_id,
                    p.payload.arrived.elapsed().as_micros() as u64,
                    if partial { Outcome::Partial } else { Outcome::Complete },
                    p.payload.trace_id,
                );
                if p.payload.trace_id != 0 {
                    shared.tracer.record(
                        p.payload.trace_id,
                        SpanKind::ReplyWrite,
                        0,
                        t_write.elapsed().as_secs_f64(),
                    );
                    shared.tracer.record(
                        p.payload.trace_id,
                        SpanKind::Total,
                        0,
                        p.payload.arrived.elapsed().as_secs_f64(),
                    );
                }
            }
            Err(_) => {
                // A failed retrieval must not leave the client blocked on
                // a reply that will never come: close its connection.
                let mut writers = shared.writers.lock().unwrap();
                if let Some(stream) = writers.remove(&p.payload.conn_id) {
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                }
            }
        }
    }
}

/// Apply one membership transition to the retriever's clustered
/// dispatcher. Infallible at this layer: failures are reported in the
/// ack (the serving loop must keep running whatever the admin sent).
fn apply_cluster_update(retriever: &mut Retriever, update: &ClusterUpdate) -> ClusterAck {
    let k = retriever.dispatcher.k;
    let Some(engine) = retriever.dispatcher.cluster_mut() else {
        return ClusterAck {
            epoch: 0,
            ok: false,
            message: "coordinator is not running a clustered dispatcher".to_string(),
        };
    };
    let outcome: crate::Result<u64> = match update.op {
        ClusterOp::Join => update
            .addr
            .parse::<std::net::SocketAddr>()
            .map_err(|_| anyhow::anyhow!("bad node address '{}'", update.addr))
            .and_then(|addr| {
                let node = RemoteNode::connect(addr, k)?;
                anyhow::ensure!(
                    node.shard() == update.shard as usize,
                    "node at {} declares shard {} but the join names shard {}",
                    update.addr,
                    node.shard(),
                    update.shard
                );
                // Same carve-shape contract the startup path enforces: a
                // node carved at a different --shards would silently
                // serve the wrong subset and corrupt the merged top-k.
                anyhow::ensure!(
                    node.n_shards() == engine.n_shards(),
                    "node at {} was carved at {} shards but the cluster has {}",
                    update.addr,
                    node.n_shards(),
                    engine.n_shards()
                );
                engine.join(ClusterNode {
                    id: update.node_id,
                    shard: update.shard as usize,
                    backend: Box::new(node),
                })
            }),
        ClusterOp::Drain => engine.drain(update.node_id),
        ClusterOp::Remove => engine.remove(update.node_id),
    };
    match outcome {
        Ok(epoch) => ClusterAck {
            epoch,
            ok: true,
            message: format!("{:?} node {} -> epoch {epoch}", update.op, update.node_id),
        },
        Err(e) => ClusterAck {
            epoch: retriever.dispatcher.cluster().map(|c| c.epoch()).unwrap_or(0),
            ok: false,
            message: format!("{e:#}"),
        },
    }
}

fn source_counter(source: RetrievalSource) -> &'static str {
    match source {
        RetrievalSource::Miss => "retrieve_miss",
        RetrievalSource::CacheHit => "retrieve_cache_hit",
        RetrievalSource::SpecHit => "retrieve_spec_hit",
    }
}

// ------------------------------------------------------- stats scraping

/// Mirror the pull-model stats (cluster engine counters, retcache hit
/// rates, admission queue depths) into the registry as absolute gauges.
/// Runs on the serving loops only — scrape threads read the registry and
/// must never touch the retriever.
fn export_side_stats(retriever: &Retriever, shared: &Shared) {
    if !shared.telemetry.enabled() {
        return;
    }
    let reg = shared.telemetry.registry();
    if let Some(c) = retriever.dispatcher.cluster() {
        let s = c.stats();
        reg.gauge("cluster.epoch").set(c.epoch());
        reg.gauge("cluster.rounds").set(s.rounds);
        reg.gauge("cluster.attempts").set(s.attempts);
        reg.gauge("cluster.retries").set(s.retries);
        reg.gauge("cluster.failovers").set(s.failovers);
        reg.gauge("cluster.hedges").set(s.hedges);
        reg.gauge("cluster.hedge_wins").set(s.hedge_wins);
        reg.gauge("cluster.breaker_trips").set(s.breaker_trips);
        reg.gauge("cluster.late_responses").set(s.late_responses);
        reg.gauge("cluster.probes").set(s.probes);
        reg.gauge("cluster.probe_mismatches").set(s.probe_mismatches);
        reg.gauge("cluster.partial_rounds").set(s.partial_rounds);
        reg.gauge("cluster.unanswered_shards").set(s.unanswered_shards);
        reg.gauge("cluster.deadline_expired_shards").set(s.deadline_expired_shards);
    }
    retriever.export_telemetry(reg);
    for (tenant, depth) in shared.admission.lock().unwrap().depths() {
        let t = tenant.to_string();
        reg.gauge_with("admission.queued", &[("tenant", t.as_str())])
            .set(depth as u64);
    }
}

/// The full stats document served over a `StatsResponse`: the telemetry
/// plane's sections (`uptime_s`, `tenants`, `slo`, `metrics`, `global`,
/// `tail`) plus the coordinator's own `server` counters and `admission`
/// queue depths. A non-empty request prefix filters registry metric
/// names, shrinking the frame for targeted pollers.
fn stats_json(req: &StatsRequest, shared: &Shared) -> Json {
    let Json::Obj(mut doc) = shared.telemetry.stats_json() else {
        return Json::Null;
    };
    if !req.prefix.is_empty() {
        for section in ["metrics", "global"] {
            if let Some(Json::Obj(groups)) = doc.get_mut(section) {
                for v in groups.values_mut() {
                    if let Json::Obj(m) = v {
                        m.retain(|k, _| k.starts_with(&req.prefix));
                    }
                }
            }
        }
    }
    let s = shared.stats.snapshot();
    doc.insert(
        "server".to_string(),
        obj(vec![
            ("received", Json::Num(s.received as f64)),
            ("replies", Json::Num(s.replies as f64)),
            ("partial", Json::Num(s.partial as f64)),
            ("shed", Json::Num(s.shed as f64)),
            ("backpressure_frames", Json::Num(s.backpressure as f64)),
            ("requests", Json::Num(s.requests as f64)),
            ("rounds", Json::Num(s.rounds as f64)),
            ("batches_ge2", Json::Num(s.batches_ge2 as f64)),
            ("max_batch", Json::Num(s.max_batch as f64)),
            ("teardowns", Json::Num(s.teardowns as f64)),
            ("accept_drops", Json::Num(s.accept_drops as f64)),
            ("nodelay_fallbacks", Json::Num(s.nodelay_fallbacks as f64)),
            ("shutdown_denied", Json::Num(s.shutdown_denied as f64)),
            ("stats_denied", Json::Num(s.stats_denied as f64)),
            ("deadline_shed", Json::Num(s.deadline_shed as f64)),
            ("shed_queue_full", Json::Num(s.shed_queue_full as f64)),
            ("shed_rate_limited", Json::Num(s.shed_rate_limited as f64)),
            ("shed_deadline", Json::Num(s.shed_deadline as f64)),
        ]),
    );
    doc.insert(
        "admission".to_string(),
        Json::Arr(
            shared
                .admission
                .lock()
                .unwrap()
                .depths()
                .into_iter()
                .map(|(t, d)| {
                    obj(vec![
                        ("tenant", Json::Num(t as f64)),
                        ("queued", Json::Num(d as f64)),
                    ])
                })
                .collect(),
        ),
    );
    Json::Obj(doc)
}

/// Build the `StatsResponse` frame for one `StatsRequest`, enforcing the
/// optional admin gate (mirrors `admin_shutdown_only`: connection 0 is
/// the admin). Denied pollers get a well-formed `{"error": ...}` body,
/// not a dropped connection — stats refusal must not kill a tenant's
/// serving stream.
fn stats_response_frame(conn_id: u64, req: &StatsRequest, shared: &Shared) -> Frame {
    let body = if shared.qos.stats_admin_only && conn_id != 0 {
        shared.stats.stats_denied.inc();
        obj(vec![(
            "error",
            Json::Str("stats are admin-only on this coordinator".to_string()),
        )])
    } else {
        stats_json(req, shared)
    };
    StatsResponse { revision: STATS_REVISION, json: body.dump() }.encode()
}

// ------------------------------------------------------------ GPU client

/// One reply from the coordinator: the retrieval result, or an explicit
/// admission-control shed telling the client to back off.
#[derive(Debug)]
pub enum Reply {
    Response(RetrieveResponse),
    Backpressure(Backpressure),
}

/// GPU-process-side client of the coordinator.
pub struct CoordinatorClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    pub gpu_id: u32,
    next_id: u64,
}

impl CoordinatorClient {
    pub fn connect(addr: SocketAddr, gpu_id: u32) -> Result<CoordinatorClient> {
        let stream =
            TcpStream::connect(addr).context("connecting to coordinator")?;
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(CoordinatorClient { stream, reader, gpu_id, next_id: 0 })
    }

    /// One blocking round trip that surfaces backpressure to the caller:
    /// either the retrieval result or the server's shed verdict.
    pub fn try_retrieve(
        &mut self,
        query: &[f32],
        lists: &[u32],
        k: usize,
        want_chunks: bool,
    ) -> Result<Reply> {
        self.try_retrieve_deadline(query, lists, k, want_chunks, 0)
    }

    /// [`try_retrieve`](Self::try_retrieve) with an end-to-end deadline
    /// budget in microseconds (0 = unbounded). The coordinator charges
    /// queueing, dispatch, scans, retries and hedges against it; an
    /// expired-in-queue request comes back as a `Backpressure` shed
    /// (reason `DeadlineExpired`), one that expires mid-scan comes back
    /// as a coverage-tagged partial result when the server's degraded
    /// policy allows it.
    pub fn try_retrieve_deadline(
        &mut self,
        query: &[f32],
        lists: &[u32],
        k: usize,
        want_chunks: bool,
        deadline_us: u64,
    ) -> Result<Reply> {
        let id = self.next_id;
        self.next_id += 1;
        RetrieveRequest {
            query_id: id,
            gpu_id: self.gpu_id,
            query: query.to_vec(),
            lists: lists.to_vec(),
            k: k as u32,
            want_chunks,
            deadline_us,
        }
        .encode()
        .write_to(&mut self.stream)?;
        let f = Frame::read_from(&mut self.reader)?;
        if f.kind == Kind::Backpressure {
            let bp = Backpressure::decode(&f)?;
            anyhow::ensure!(bp.query_id == id, "backpressure id mismatch");
            return Ok(Reply::Backpressure(bp));
        }
        let resp = RetrieveResponse::decode(&f)?;
        anyhow::ensure!(resp.query_id == id, "response id mismatch");
        Ok(Reply::Response(resp))
    }

    /// Fetch the coordinator's live stats document over the protocol
    /// (`StatsRequest`/`StatsResponse`, revision-tagged). `prefix`
    /// filters registry metric names server-side (`""` = everything).
    /// Powers `chameleon top --remote`; callers must not interleave this
    /// with in-flight pipelined retrievals on the same connection.
    pub fn stats(&mut self, prefix: &str) -> Result<Json> {
        StatsRequest { prefix: prefix.to_string(), flags: 0 }
            .encode()
            .write_to(&mut self.stream)?;
        let f = Frame::read_from(&mut self.reader)?;
        let resp = StatsResponse::decode(&f)?;
        // The JSON body is self-describing; newer revisions only add
        // keys, so any revision >= 1 is readable here.
        anyhow::ensure!(resp.revision >= 1, "bad stats revision 0");
        Json::parse(&resp.json)
            .map_err(|e| anyhow::anyhow!("malformed stats JSON from coordinator: {e:?}"))
    }

    /// One blocking retrieval round trip (the per-token path for
    /// decoder-only models). A shed is an error at this level; callers
    /// that want to back off and retry use
    /// [`try_retrieve`](Self::try_retrieve).
    pub fn retrieve(
        &mut self,
        query: &[f32],
        lists: &[u32],
        k: usize,
        want_chunks: bool,
    ) -> Result<RetrieveResponse> {
        match self.try_retrieve(query, lists, k, want_chunks)? {
            Reply::Response(r) => Ok(r),
            Reply::Backpressure(bp) => anyhow::bail!(
                "request shed by admission control (tenant {}, reason {}, retry in {}us)",
                bp.tenant,
                bp.reason,
                bp.retry_after_us
            ),
        }
    }

    /// Send a window of requests back-to-back, then collect the replies —
    /// the concurrent coordinator answers one connection's *retrieval*
    /// replies in FIFO order, so pipelining feeds the batcher without
    /// waiting a round trip per query. Valid while the tenant is within
    /// its admission limits: a `Backpressure` frame (which may overtake
    /// FIFO replies) is an error here.
    pub fn retrieve_pipelined(
        &mut self,
        queries: &[&[f32]],
        k: usize,
        want_chunks: bool,
    ) -> Result<Vec<RetrieveResponse>> {
        let base = self.next_id;
        self.next_id += queries.len() as u64;
        for (i, q) in queries.iter().enumerate() {
            RetrieveRequest {
                query_id: base + i as u64,
                gpu_id: self.gpu_id,
                query: q.to_vec(),
                lists: Vec::new(),
                k: k as u32,
                want_chunks,
                deadline_us: 0,
            }
            .encode()
            .write_to(&mut self.stream)?;
        }
        let mut out = Vec::with_capacity(queries.len());
        for i in 0..queries.len() {
            let f = Frame::read_from(&mut self.reader)?;
            if f.kind == Kind::Backpressure {
                let bp = Backpressure::decode(&f)?;
                anyhow::bail!(
                    "pipelined request {} shed by admission control (reason {})",
                    bp.query_id,
                    bp.reason
                );
            }
            let resp = RetrieveResponse::decode(&f)?;
            anyhow::ensure!(
                resp.query_id == base + i as u64,
                "pipelined response out of order"
            );
            out.push(resp);
        }
        Ok(out)
    }

    pub fn shutdown_coordinator(&mut self) {
        let _ = Frame { kind: Kind::Shutdown, payload: vec![] }.write_to(&mut self.stream);
    }

    /// Submit a live cluster-membership transition and wait for the
    /// coordinator's ack. Call with no retrievals outstanding on this
    /// connection (replies are FIFO per connection, so an interleaved
    /// pipeline would race the ack ordering).
    pub fn cluster_update(&mut self, update: &ClusterUpdate) -> Result<ClusterAck> {
        update.encode().write_to(&mut self.stream)?;
        let f = Frame::read_from(&mut self.reader)?;
        ClusterAck::decode(&f)
    }
}
