//! The accelerator-ratio analysis of Fig 13: for each RALM configuration,
//! how many GPUs does one ChamVS vector-search engine saturate?
//!
//! ratio = ChamVS throughput (queries/s) / per-GPU retrieval demand
//! (queries/s). Demand = token throughput / retrieval interval. The paper
//! reports ratios from 0.2 to 442, concluding that a monolithic
//! fixed-ratio server cannot serve all configurations.

use crate::config::{DatasetConfig, ModelConfig};
use crate::hwmodel::fpga::FpgaModel;
use crate::hwmodel::gpu::GpuModel;

/// One Fig 13 row.
#[derive(Clone, Debug)]
pub struct RatioRow {
    pub model: &'static str,
    pub dataset: &'static str,
    pub interval: usize,
    pub batch: usize,
    /// Tokens/s one GPU sustains at this batch.
    pub gpu_tokens_per_s: f64,
    /// Queries/s one ChamVS engine sustains.
    pub chamvs_qps: f64,
    /// GPUs needed to saturate the ChamVS engine.
    pub gpus_per_chamvs: f64,
}

/// Compute the ratio for one (model, dataset, interval, batch) point.
pub fn accelerator_ratio(
    model: &'static ModelConfig,
    ds: &'static DatasetConfig,
    interval: usize,
    batch: usize,
    gpu: &GpuModel,
    fpga: &FpgaModel,
) -> RatioRow {
    // GPU side: steady-state tokens/s for a batch of sequences, including
    // the amortized retrieval-adjacent work that stays on the GPU
    // (query generation + encoder passes for EncDec).
    let decode_s = gpu.decode_step_latency(model, batch);
    let encode_s = if model.is_encdec() {
        gpu.encode_latency(model, batch) / interval as f64
    } else {
        0.0
    };
    let step_s = decode_s + encode_s;
    let tokens_per_s = batch as f64 / step_s;
    // Retrieval demand: every sequence retrieves once per `interval`.
    let demand_qps = tokens_per_s / interval as f64;

    // ChamVS side: pipelined scan throughput of one memory node.
    let codes_per_query =
        ds.n_paper as f64 * ds.nprobe as f64 / ds.nlist_paper as f64;
    let scan_s = fpga
        .query_latency(codes_per_query as usize, ds.m, ds.nprobe, model.k)
        .scan_s;
    let lut_s = fpga.query_latency(1, ds.m, ds.nprobe, model.k).lut_s;
    let chamvs_qps = 1.0 / scan_s.max(lut_s);

    RatioRow {
        model: model.name,
        dataset: ds.name,
        interval,
        batch,
        gpu_tokens_per_s: tokens_per_s,
        chamvs_qps,
        gpus_per_chamvs: chamvs_qps / demand_qps,
    }
}

/// The full Fig 13 sweep: every Table 2 model at its intervals, on its
/// dataset, at the paper's latency/throughput batch sizes.
pub fn fig13_sweep(gpu: &GpuModel, fpga: &FpgaModel) -> Vec<RatioRow> {
    use crate::config::{DEC_L, DEC_S, ENCDEC_L, ENCDEC_S, SYN1024, SYN512};
    let mut rows = Vec::new();
    let cases: [(&'static ModelConfig, &'static DatasetConfig, &[usize], &[usize]); 4] = [
        (&DEC_S, &SYN512, &[1], &[1, 64]),
        (&DEC_L, &SYN1024, &[1], &[1, 8]),
        (&ENCDEC_S, &SYN512, &[8, 64, 512], &[1, 64]),
        (&ENCDEC_L, &SYN1024, &[8, 64, 512], &[1, 8]),
    ];
    for (model, ds, intervals, batches) in cases {
        for &interval in intervals {
            for &batch in batches {
                rows.push(accelerator_ratio(model, ds, interval, batch, gpu, fpga));
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DEC_S, ENCDEC_L, SYN1024, SYN512};

    #[test]
    fn ratio_spans_orders_of_magnitude() {
        // Fig 13: 0.2 .. 442 GPUs per ChamVS engine.
        let (g, f) = (GpuModel::default(), FpgaModel::default());
        let rows = fig13_sweep(&g, &f);
        let min = rows.iter().map(|r| r.gpus_per_chamvs).fold(f64::MAX, f64::min);
        let max = rows.iter().map(|r| r.gpus_per_chamvs).fold(0.0, f64::max);
        assert!(min < 2.0, "min {min}");
        assert!(max > 50.0, "max {max}");
        assert!(max / min > 100.0, "span {min}..{max}");
    }

    #[test]
    fn interval_1_small_model_needs_fractional_gpus() {
        // Dec-S at interval 1, large batch: retrieval-bound => < a few
        // GPUs saturate the search engine.
        let (g, f) = (GpuModel::default(), FpgaModel::default());
        let r = accelerator_ratio(&DEC_S, &SYN512, 1, 64, &g, &f);
        assert!(r.gpus_per_chamvs < 5.0, "{}", r.gpus_per_chamvs);
    }

    #[test]
    fn rare_retrieval_large_model_needs_many_gpus() {
        let (g, f) = (GpuModel::default(), FpgaModel::default());
        let r = accelerator_ratio(&ENCDEC_L, &SYN1024, 512, 1, &g, &f);
        assert!(r.gpus_per_chamvs > 50.0, "{}", r.gpus_per_chamvs);
    }

    #[test]
    fn demand_decreases_with_interval() {
        let (g, f) = (GpuModel::default(), FpgaModel::default());
        let r8 = accelerator_ratio(&ENCDEC_L, &SYN1024, 8, 8, &g, &f);
        let r512 = accelerator_ratio(&ENCDEC_L, &SYN1024, 512, 8, &g, &f);
        assert!(r512.gpus_per_chamvs > r8.gpus_per_chamvs);
    }
}
