//! The PJRT client wrapper: compiles HLO-text artifacts once and executes
//! them with cached parameter buffers.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute_b`. Artifacts are lowered with
//! return_tuple=False, so each output arrives as its own `PjRtBuffer` —
//! recurrent state (the KV cache) is fed back without host round-trips.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;
use crate::util::rng::Rng;

/// Process-wide PJRT runtime: one CPU client + compiled-executable cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory.
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn compile(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = spec
            .hlo_path
            .to_str()
            .context("non-utf8 artifact path")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("XLA compile of artifact '{name}'"))?,
        );
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Build an [`Executor`] with parameters materialized deterministically
    /// from the manifest's init metadata.
    pub fn executor(&self, name: &str, seed: u64) -> Result<Executor> {
        let spec = self.manifest.get(name)?.clone();
        let exe = self.compile(name)?;
        let mut rng = Rng::new(seed);
        let mut param_bufs = Vec::new();
        let mut param_srcs = Vec::new();
        for meta in &spec.inputs {
            if meta.is_param {
                let host = HostTensor::init_param(meta, &mut rng);
                let lit = host.to_literal()?;
                let buf = self
                    .client
                    .buffer_from_host_literal(None, &lit)
                    .with_context(|| format!("uploading param {}", meta.name))?;
                param_bufs.push(buf);
                // Retain the source literal: the device copy is async and
                // reads it on a worker thread (see call()'s safety note).
                param_srcs.push(lit);
            }
        }
        Ok(Executor { spec, exe, param_bufs, param_srcs, client: self.client.clone() })
    }
}

/// A compiled artifact plus its resident parameter buffers.
///
/// Call protocol: `call` takes the non-param ("arg") inputs in manifest
/// order as host tensors and returns every output as a host tensor.
///
/// SAFETY NOTE: `buffer_from_host_literal` copies the literal
/// *asynchronously* on a TFRT worker thread; dropping the source `Literal`
/// before the copy runs is a use-after-free (observed as a flaky SIGSEGV
/// in `ShapeUtil::ByteSizeOfElements`). Every upload therefore keeps its
/// literal alive until a synchronizing event: parameter source literals
/// are retained in `param_srcs`, and `call` holds per-call literals until
/// the outputs have been fetched (output sync transitively waits on input
/// definition).
pub struct Executor {
    pub spec: ArtifactSpec,
    exe: Arc<xla::PjRtLoadedExecutable>,
    param_bufs: Vec<xla::PjRtBuffer>,
    param_srcs: Vec<xla::Literal>,
    client: xla::PjRtClient,
}

impl Executor {
    /// Number of non-parameter inputs expected per call.
    pub fn n_args(&self) -> usize {
        self.spec.args().count()
    }

    /// Execute with host-tensor args; all outputs copied back to host.
    ///
    /// Multi-output artifacts come back from this xla_extension as ONE
    /// tuple buffer (PJRT does not untuple here); the tuple is decomposed
    /// on the host transparently.
    pub fn call(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        // Upload args, keeping the source literals alive (see struct doc).
        let lits = args.iter().map(HostTensor::to_literal).collect::<Result<Vec<_>>>()?;
        let uploaded = lits
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = uploaded.iter().collect();
        let outs = self.call_buffers(&refs)?;
        // Fetching outputs waits for the computation, which waits for the
        // input copies — only then is dropping `lits` safe.
        let host = if outs.len() == 1 && self.spec.outputs.len() > 1 {
            let mut lit = outs[0].to_literal_sync()?;
            let parts = lit.decompose_tuple()?;
            parts.iter().map(HostTensor::from_literal).collect()
        } else {
            outs.iter()
                .map(|b| HostTensor::from_literal(&b.to_literal_sync()?))
                .collect()
        };
        drop(lits);
        host
    }

    /// Execute with explicit arg buffers (device-resident state loop).
    pub fn call_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        anyhow::ensure!(
            args.len() == self.n_args(),
            "artifact '{}' expects {} args, got {}",
            self.spec.name,
            self.n_args(),
            args.len()
        );
        let mut all: Vec<&xla::PjRtBuffer> = self.param_bufs.iter().collect();
        all.extend_from_slice(args);
        let mut outs = self.exe.execute_b(&all)?;
        anyhow::ensure!(!outs.is_empty(), "no replica outputs");
        Ok(std::mem::take(&mut outs[0]))
    }

    /// Copy one output buffer to host.
    pub fn fetch(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        HostTensor::from_literal(&buf.to_literal_sync()?)
    }

    /// Replace a resident parameter with new host values (training loop:
    /// adopt updated weights/optimizer state for the next step). The
    /// source literal is retained, replacing the previous one.
    pub fn set_param(&mut self, idx: usize, t: &HostTensor) -> Result<()> {
        let lit = t.to_literal()?;
        let buf = self.client.buffer_from_host_literal(None, &lit)?;
        self.param_bufs[idx] = buf;
        self.param_srcs[idx] = lit;
        Ok(())
    }

    pub fn n_params(&self) -> usize {
        self.param_bufs.len()
    }
}
