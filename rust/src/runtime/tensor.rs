//! Host-side tensors and their conversion to/from XLA literals.

use anyhow::{bail, Result};

use super::manifest::{DType, TensorMeta};
use crate::util::rng::Rng;

/// A host tensor matching one artifact input/output.
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros(meta: &TensorMeta) -> HostTensor {
        match meta.dtype {
            DType::F32 => HostTensor::F32 {
                shape: meta.shape.clone(),
                data: vec![0.0; meta.element_count()],
            },
            DType::I32 => HostTensor::I32 {
                shape: meta.shape.clone(),
                data: vec![0; meta.element_count()],
            },
        }
    }

    /// Deterministic parameter init: normal(0, init_scale), mirroring the
    /// jax-side init distributions recorded in the manifest.
    pub fn init_param(meta: &TensorMeta, rng: &mut Rng) -> HostTensor {
        match meta.dtype {
            DType::F32 => {
                let n = meta.element_count();
                let data =
                    (0..n).map(|_| rng.normal() * meta.init_scale).collect();
                HostTensor::F32 { shape: meta.shape.clone(), data }
            }
            DType::I32 => HostTensor::zeros(meta),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read back from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(shape: &[usize], dtype: DType, scale: f32) -> TensorMeta {
        TensorMeta {
            name: "t".into(),
            shape: shape.to_vec(),
            dtype,
            is_param: true,
            init_scale: scale,
        }
    }

    #[test]
    fn zeros_shapes() {
        let t = HostTensor::zeros(&meta(&[2, 3], DType::F32, 0.0));
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn param_init_scale() {
        let mut rng = Rng::new(1);
        let t = HostTensor::init_param(&meta(&[100, 100], DType::F32, 0.02), &mut rng);
        let data = t.as_f32().unwrap();
        let std = (data.iter().map(|x| x * x).sum::<f32>() / data.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.002, "std {std}");
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[3], vec![7, -1, 5]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[7, -1, 5]);
    }

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::i32(&[1], vec![1]);
        assert!(t.as_f32().is_err());
    }
}
