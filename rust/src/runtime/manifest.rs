//! Typed view of `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One input or output tensor of an artifact.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    /// "param" inputs are materialized once (deterministic init) and kept
    /// as device buffers; "arg" inputs change per call.
    pub is_param: bool,
    /// Init stddev for params (aot.py records the jax init scale).
    pub init_scale: f32,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub static_cfg: Json,
}

impl ArtifactSpec {
    pub fn params(&self) -> impl Iterator<Item = &TensorMeta> {
        self.inputs.iter().filter(|t| t.is_param)
    }

    pub fn args(&self) -> impl Iterator<Item = &TensorMeta> {
        self.inputs.iter().filter(|t| !t.is_param)
    }

    /// Static config integer (e.g. "m", "n_codes", "knn_k").
    pub fn static_usize(&self, key: &str) -> Option<usize> {
        self.static_cfg.get(key).and_then(Json::as_usize)
    }

    pub fn static_f64(&self, key: &str) -> Option<f64> {
        self.static_cfg.get(key).and_then(Json::as_f64)
    }
}

/// The parsed manifest: artifact name -> spec.
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            artifacts.insert(name.clone(), parse_spec(&dir, name, spec)?);
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact '{name}' not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn parse_spec(dir: &Path, name: &str, spec: &Json) -> Result<ArtifactSpec> {
    let file = spec
        .get("file")
        .and_then(Json::as_str)
        .with_context(|| format!("artifact {name}: missing file"))?;
    let parse_tensors = |key: &str| -> Result<Vec<TensorMeta>> {
        let arr = spec
            .get(key)
            .and_then(Json::as_arr)
            .with_context(|| format!("artifact {name}: missing {key}"))?;
        arr.iter().map(parse_tensor).collect()
    };
    Ok(ArtifactSpec {
        name: name.to_string(),
        hlo_path: dir.join(file),
        inputs: parse_tensors("inputs")?,
        outputs: parse_tensors("outputs")?,
        static_cfg: spec.get("static").cloned().unwrap_or(Json::Null),
    })
}

fn parse_tensor(t: &Json) -> Result<TensorMeta> {
    let name = t.get("name").and_then(Json::as_str).context("tensor name")?;
    let shape = t
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor shape")?
        .iter()
        .map(|d| d.as_usize().context("shape dim"))
        .collect::<Result<Vec<_>>>()?;
    let dtype = match t.get("dtype").and_then(Json::as_str) {
        Some("f32") => DType::F32,
        Some("i32") => DType::I32,
        other => bail!("unsupported dtype {other:?}"),
    };
    let is_param = t.get("kind").and_then(Json::as_str) == Some("param");
    let init_scale =
        t.get("init_scale").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    Ok(TensorMeta { name: name.to_string(), shape, dtype, is_param, init_scale })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("cham_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let json = r#"{"artifacts":{"toy":{"file":"toy.hlo.txt",
            "inputs":[
              {"name":"w","shape":[4,4],"dtype":"f32","kind":"param","init_scale":0.5},
              {"name":"x","shape":[4],"dtype":"f32","kind":"arg"},
              {"name":"t","shape":[1],"dtype":"i32","kind":"arg"}],
            "outputs":[{"name":"y","shape":[4],"dtype":"f32"}],
            "static":{"m":16,"cost":{"flops":123}}}}}"#;
        std::fs::write(dir.join("manifest.json"), json).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.params().count(), 1);
        assert_eq!(a.args().count(), 2);
        assert_eq!(a.inputs[0].init_scale, 0.5);
        assert_eq!(a.inputs[2].dtype, DType::I32);
        assert_eq!(a.static_usize("m"), Some(16));
        assert_eq!(a.outputs[0].element_count(), 4);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
