//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! on the XLA CPU client from the request path — the rust half of the
//! L2->L3 bridge. Python never runs here.
//!
//! The interchange format is HLO *text*: jax >= 0.5 serializes protos with
//! 64-bit instruction ids that this crate's xla_extension (0.5.1) rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executor, Runtime};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorMeta};
pub use tensor::HostTensor;
