//! Memory layout policies of ChamVS.mem (paper Sec 4.3):
//! * per-channel interleaving of each list's codes so every DDR channel
//!   carries an equal share of a scan, and
//! * the two distributed partitioning schemes (vector-sharded vs
//!   list-sharded) whose load-balance behaviour Fig 9/10 depend on.

/// How database vectors are split across disaggregated memory nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Partitioning {
    /// Every node holds all IVF lists but only 1/N of the vectors per list
    /// (the paper's choice: scan load is always balanced).
    VectorSharded,
    /// Each node holds a disjoint subset of the lists (risk: all probed
    /// lists may land on one node).
    ListSharded,
}

/// Assignment of one list's vectors to memory channels, interleaved in
/// 64-byte AXI beats (paper: "evenly distributes the quantized vectors
/// ... among all memory channels").
#[derive(Clone, Debug)]
pub struct ChannelLayout {
    pub n_channels: usize,
    /// Per-channel vector counts for the list.
    pub per_channel: Vec<usize>,
}

impl ChannelLayout {
    /// Distribute `n` vectors of `m`-byte codes over `n_channels` channels
    /// round-robin per vector.
    pub fn balance(n: usize, n_channels: usize) -> ChannelLayout {
        let base = n / n_channels;
        let extra = n % n_channels;
        let per_channel =
            (0..n_channels).map(|c| base + usize::from(c < extra)).collect();
        ChannelLayout { n_channels, per_channel }
    }

    /// Max / mean imbalance across channels (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let max = *self.per_channel.iter().max().unwrap() as f64;
        let mean = self.per_channel.iter().sum::<usize>() as f64
            / self.n_channels as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Cycles to stream this list through the decoding units: the slowest
    /// channel dominates (each channel feeds its own units).
    pub fn scan_cycles(&self, codes_per_cycle_per_channel: f64) -> f64 {
        let max = *self.per_channel.iter().max().unwrap() as f64;
        max / codes_per_cycle_per_channel
    }
}

/// Split the vectors of every list across `n_nodes` (VectorSharded), or
/// assign whole lists round-robin (ListSharded). Returns, per node, the
/// number of vectors it scans for a given probe set.
pub fn scan_load_per_node(
    list_sizes: &[usize],
    probed: &[u32],
    n_nodes: usize,
    part: Partitioning,
) -> Vec<usize> {
    let mut load = vec![0usize; n_nodes];
    match part {
        Partitioning::VectorSharded => {
            for &l in probed {
                let n = list_sizes[l as usize];
                let base = n / n_nodes;
                let extra = n % n_nodes;
                for (node, slot) in load.iter_mut().enumerate() {
                    *slot += base + usize::from(node < extra);
                }
            }
        }
        Partitioning::ListSharded => {
            for &l in probed {
                load[l as usize % n_nodes] += list_sizes[l as usize];
            }
        }
    }
    load
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channel_balance_exact() {
        let l = ChannelLayout::balance(1000, 4);
        assert_eq!(l.per_channel, vec![250; 4]);
        assert!((l.imbalance() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn channel_balance_remainder() {
        let l = ChannelLayout::balance(10, 4);
        assert_eq!(l.per_channel.iter().sum::<usize>(), 10);
        assert!(l.per_channel.iter().max().unwrap() - l.per_channel.iter().min().unwrap() <= 1);
    }

    #[test]
    fn vector_sharding_always_balanced() {
        let mut rng = Rng::new(1);
        let sizes: Vec<usize> = (0..100).map(|_| 50 + rng.below(1000)).collect();
        let probed: Vec<u32> = (0..32).map(|_| rng.below(100) as u32).collect();
        let load = scan_load_per_node(&sizes, &probed, 4, Partitioning::VectorSharded);
        let max = *load.iter().max().unwrap() as f64;
        let min = *load.iter().min().unwrap() as f64;
        assert!(max / min < 1.01, "{load:?}");
    }

    #[test]
    fn list_sharding_can_skew() {
        // All probed lists on node 0 (ids ≡ 0 mod n_nodes).
        let sizes = vec![100usize; 64];
        let probed: Vec<u32> = (0..8).map(|i| i * 4).collect();
        let load = scan_load_per_node(&sizes, &probed, 4, Partitioning::ListSharded);
        assert_eq!(load[0], 800);
        assert_eq!(load[1] + load[2] + load[3], 0);
    }

    #[test]
    fn loads_conserve_totals() {
        let mut rng = Rng::new(2);
        let sizes: Vec<usize> = (0..64).map(|_| rng.below(500)).collect();
        let probed: Vec<u32> = (0..16).map(|_| rng.below(64) as u32).collect();
        let total: usize = probed.iter().map(|&l| sizes[l as usize]).sum();
        for part in [Partitioning::VectorSharded, Partitioning::ListSharded] {
            let load = scan_load_per_node(&sizes, &probed, 4, part);
            assert_eq!(load.iter().sum::<usize>(), total, "{part:?}");
        }
    }

    #[test]
    fn scan_cycles_uses_slowest_channel() {
        let l = ChannelLayout { n_channels: 2, per_channel: vec![10, 30] };
        assert!((l.scan_cycles(2.0) - 15.0).abs() < 1e-12);
    }
}
