//! Index persistence: save/load a trained IVF-PQ index (and shards) in a
//! simple length-prefixed binary format, so memory nodes can boot from a
//! file instead of retraining — the practical deployment path for the
//! paper's "the coordinator loads the database into node DRAM at init".
//!
//! Format (little-endian):
//!   magic "CHAMIDX1" | d u32 | m u32 | nlist u32
//!   | coarse centroids f32[nlist*d]
//!   | pq centroids f32[m*256*dsub]
//!   | per list: len u32, codes u8[len*m], ids u64[len]

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};
use byteorder::{LittleEndian as LE, ReadBytesExt, WriteBytesExt};

use super::index::IvfPqIndex;
use crate::pq::codebook::{PqCodebook, KSUB};

const MAGIC: &[u8; 8] = b"CHAMIDX1";

impl IvfPqIndex {
    /// Serialize to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_u32::<LE>(self.d as u32)?;
        w.write_u32::<LE>(self.m as u32)?;
        w.write_u32::<LE>(self.nlist as u32)?;
        write_f32s(&mut w, &self.centroids)?;
        write_f32s(&mut w, &self.pq.centroids)?;
        for l in 0..self.nlist {
            let ids = &self.list_ids[l];
            w.write_u32::<LE>(ids.len() as u32)?;
            w.write_all(&self.list_codes[l])?;
            for &id in ids {
                w.write_u64::<LE>(id)?;
            }
        }
        w.flush()?;
        Ok(())
    }

    /// Deserialize from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<IvfPqIndex> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a chameleon index file");
        }
        let d = r.read_u32::<LE>()? as usize;
        let m = r.read_u32::<LE>()? as usize;
        let nlist = r.read_u32::<LE>()? as usize;
        if m == 0 || d == 0 || d % m != 0 || nlist == 0 || nlist > 1 << 24 {
            bail!("corrupt index header: d={d} m={m} nlist={nlist}");
        }
        let dsub = d / m;
        let centroids = read_f32s(&mut r, nlist * d)?;
        let pq_centroids = read_f32s(&mut r, m * KSUB * dsub)?;
        let mut list_codes = Vec::with_capacity(nlist);
        let mut list_ids = Vec::with_capacity(nlist);
        for _ in 0..nlist {
            let len = r.read_u32::<LE>()? as usize;
            if len > 1 << 28 {
                bail!("corrupt list length {len}");
            }
            let mut codes = vec![0u8; len * m];
            r.read_exact(&mut codes)?;
            let mut ids = Vec::with_capacity(len);
            for _ in 0..len {
                ids.push(r.read_u64::<LE>()?);
            }
            list_codes.push(codes);
            list_ids.push(ids);
        }
        Ok(IvfPqIndex {
            d,
            m,
            nlist,
            centroids,
            pq: PqCodebook { d, m, centroids: pq_centroids },
            list_codes,
            list_ids,
        })
    }
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    for &x in xs {
        w.write_f32::<LE>(x)?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.read_f32::<LE>()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cham_{}_{}", name, std::process::id()))
    }

    fn toy() -> IvfPqIndex {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (1200, 16, 4, 16);
        let data = rng.normal_vec(n * d);
        IvfPqIndex::build(&data, n, d, m, nlist, 2)
    }

    #[test]
    fn roundtrip_preserves_search_results() {
        let idx = toy();
        let path = tmp("roundtrip");
        idx.save(&path).unwrap();
        let back = IvfPqIndex::load(&path).unwrap();
        assert_eq!(back.d, idx.d);
        assert_eq!(back.len(), idx.len());
        let mut rng = Rng::new(9);
        for _ in 0..5 {
            let q = rng.normal_vec(idx.d);
            let (a_ids, a_d) = idx.search(&q, 8, 10);
            let (b_ids, b_d) = back.search(&q, 8, 10);
            assert_eq!(a_ids, b_ids);
            assert_eq!(a_d, b_d);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shard_carve_roundtrips_through_persistence() {
        // Node restart-from-disk contract for failover: a shard carved
        // from a loaded index must be byte-identical (codes, ids AND the
        // flat (offset, len) extents) to one carved from the original —
        // at every (shard, n_shards) a replicated cluster uses.
        use crate::ivf::shard::Shard;
        let idx = toy();
        let path = tmp("shard_carve");
        idx.save(&path).unwrap();
        let back = IvfPqIndex::load(&path).unwrap();
        for n_shards in [1usize, 2, 3] {
            for s in 0..n_shards {
                let a = Shard::carve(&idx, s, n_shards);
                let b = Shard::carve(&back, s, n_shards);
                assert_eq!(a.m, b.m);
                assert_eq!(a.codes, b.codes, "codes, shard {s}/{n_shards}");
                assert_eq!(a.ids, b.ids, "ids, shard {s}/{n_shards}");
                assert_eq!(a.extents, b.extents, "extents, shard {s}/{n_shards}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage");
        std::fs::write(&path, b"definitely not an index").unwrap();
        assert!(IvfPqIndex::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let idx = toy();
        let path = tmp("trunc");
        idx.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(IvfPqIndex::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(IvfPqIndex::load("/nonexistent/idx.bin").is_err());
    }
}
