//! The combined IVF-PQ index: coarse quantizer + per-list PQ codes.
//!
//! Training follows the paper's setup (Sec 6.1): `nlist ~= sqrt(n)`
//! clusters trained on a sample, PQ trained on residual-free raw vectors
//! (as Faiss's IndexIVFPQ with `by_residual=false`, matching the
//! accelerator's LUT-per-query design which uses one table for all lists).

use crate::kselect::FusedSelector;
use crate::pq::codebook::PqCodebook;
use crate::pq::kmeans::{kmeans, nearest};
use crate::pq::scan::{build_lut, scan_list_into_sink};

/// A fully-trained IVF-PQ index with encoded database.
pub struct IvfPqIndex {
    pub d: usize,
    pub m: usize,
    pub nlist: usize,
    /// (nlist, d) coarse centroids.
    pub centroids: Vec<f32>,
    pub pq: PqCodebook,
    /// Per-list PQ codes, list l: (len_l, m) row-major.
    pub list_codes: Vec<Vec<u8>>,
    /// Per-list global vector ids, aligned with `list_codes` rows.
    pub list_ids: Vec<Vec<u64>>,
}

impl IvfPqIndex {
    /// Train coarse quantizer + PQ and encode the whole database.
    pub fn build(
        data: &[f32],
        n: usize,
        d: usize,
        m: usize,
        nlist: usize,
        seed: u64,
    ) -> IvfPqIndex {
        assert_eq!(data.len(), n * d);
        // Coarse quantizer on a sample (Faiss uses ~max(256*nlist, all)).
        let train_n = n.min(64 * nlist).max(nlist);
        let coarse = kmeans(&data[..train_n * d], train_n, d, nlist, 10, seed);
        // PQ codebook trained on a sample of raw vectors.
        let pq_n = n.min(20_000).max(256);
        let pq = PqCodebook::train(&data[..pq_n * d], pq_n, d, m, seed ^ 0x9E37);

        let mut list_codes: Vec<Vec<u8>> = vec![Vec::new(); nlist];
        let mut list_ids: Vec<Vec<u64>> = vec![Vec::new(); nlist];
        let mut code = vec![0u8; m];
        for i in 0..n {
            let v = &data[i * d..(i + 1) * d];
            let (l, _) = nearest(v, &coarse.centroids, nlist, d);
            pq.encode_one(v, &mut code);
            list_codes[l].extend_from_slice(&code);
            list_ids[l].push(i as u64);
        }
        IvfPqIndex {
            d,
            m,
            nlist,
            centroids: coarse.centroids,
            pq,
            list_codes,
            list_ids,
        }
    }

    /// Number of encoded vectors.
    pub fn len(&self) -> usize {
        self.list_ids.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scan the IVF index: ids of the `nprobe` nearest coarse centroids.
    ///
    /// Partial selection: `select_nth_unstable_by` partitions the nprobe
    /// nearest to the front in O(nlist), and only that prefix is sorted —
    /// a full O(nlist log nlist) sort just to keep nprobe entries was the
    /// index-scan tax at paper-scale nlist. The `(dist, list id)` key
    /// reproduces the old stable full sort's output order exactly.
    pub fn probe(&self, query: &[f32], nprobe: usize) -> Vec<u32> {
        let take = nprobe.min(self.nlist);
        if take == 0 {
            return Vec::new();
        }
        let mut dists: Vec<(f32, u32)> = (0..self.nlist)
            .map(|l| {
                let c = &self.centroids[l * self.d..(l + 1) * self.d];
                let dist: f32 =
                    query.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                (dist, l as u32)
            })
            .collect();
        let by_dist_then_list = |a: &(f32, u32), b: &(f32, u32)| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        };
        if take < self.nlist {
            dists.select_nth_unstable_by(take - 1, by_dist_then_list);
            dists.truncate(take);
        }
        dists.sort_unstable_by(by_dist_then_list);
        dists.iter().map(|&(_, l)| l).collect()
    }

    /// Full CPU search: probe + fused ADC scan+select (the monolithic
    /// `CPU` baseline of Fig 9). Distances stream straight into the
    /// fused selector — O(N log k) with no intermediate distance buffer,
    /// bit-identical to the old scan-everything-then-full-sort pipeline.
    pub fn search(&self, query: &[f32], nprobe: usize, k: usize) -> (Vec<u64>, Vec<f32>) {
        let lists = self.probe(query, nprobe);
        let lut = build_lut(&self.pq, query);
        let mut sel = FusedSelector::new(k);
        let mut scratch = Vec::new();
        let mut order = 0u64;
        for &l in &lists {
            let ids = &self.list_ids[l as usize];
            if ids.is_empty() {
                continue;
            }
            scan_list_into_sink(
                &self.list_codes[l as usize],
                self.m,
                &lut,
                ids,
                order,
                &mut scratch,
                &mut sel,
            );
            order += ids.len() as u64;
        }
        let mut best = Vec::with_capacity(k);
        sel.emit_into(&mut best);
        (
            best.iter().map(|&(_, i)| i).collect(),
            best.iter().map(|&(d, _)| d).collect(),
        )
    }

    /// Total vectors that would be scanned for a probe set (cost model).
    pub fn scan_count(&self, lists: &[u32]) -> usize {
        lists.iter().map(|&l| self.list_ids[l as usize].len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::flat::flat_search;
    use crate::util::rng::Rng;

    fn toy_index(seed: u64) -> (IvfPqIndex, Vec<f32>, usize, usize) {
        let mut rng = Rng::new(seed);
        let (n, d, m, nlist) = (4000, 32, 8, 64);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 7), data, n, d)
    }

    #[test]
    fn all_vectors_indexed_once() {
        let (idx, _, n, _) = toy_index(1);
        assert_eq!(idx.len(), n);
        let mut seen: Vec<u64> = idx.list_ids.iter().flatten().cloned().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u64).collect::<Vec<_>>());
    }

    #[test]
    fn probe_returns_nearest_lists() {
        let (idx, data, _, d) = toy_index(2);
        let lists = idx.probe(&data[..d], 8);
        assert_eq!(lists.len(), 8);
        // The probed lists must include the list that holds the vector
        // itself (query == database vector 0).
        let holder = idx
            .list_ids
            .iter()
            .position(|ids| ids.contains(&0))
            .unwrap() as u32;
        assert!(lists.contains(&holder), "lists {lists:?} miss {holder}");
    }

    #[test]
    fn recall_at_k_reasonable() {
        // With nprobe covering half the lists, R@10 should be high even
        // for random gaussian data (paper gets 93-94% @ 0.1% scanned on
        // real datasets; random data needs a larger fraction).
        let (idx, data, n, d) = toy_index(3);
        let mut rng = Rng::new(11);
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..20 {
            let q = rng.normal_vec(d);
            let (got, _) = idx.search(&q, 32, 10);
            let (exact, _) = flat_search(&data, n, d, &q, 10);
            total += 10;
            hits += got.iter().filter(|g| exact.contains(&(**g as u32))).count();
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.5, "R@10 = {recall}");
    }

    #[test]
    fn search_results_sorted_and_unique() {
        let (idx, _, _, d) = toy_index(4);
        let mut rng = Rng::new(5);
        let q = rng.normal_vec(d);
        let (ids, dists) = idx.search(&q, 16, 50);
        assert_eq!(ids.len(), 50);
        assert!(dists.windows(2).all(|w| w[0] <= w[1]));
        let mut u = ids.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 50);
    }

    #[test]
    fn scan_count_accumulates() {
        let (idx, _, n, _) = toy_index(6);
        let all: Vec<u32> = (0..idx.nlist as u32).collect();
        assert_eq!(idx.scan_count(&all), n);
    }
}
