//! IVF (inverted-file) index built from scratch (paper Sec 2.2):
//! a coarse k-means quantizer partitions the database into `nlist`
//! clusters; queries scan only the `nprobe` nearest lists.

pub mod index;
pub mod layout;
pub mod persist;
pub mod shard;
pub mod update;

pub use index::IvfPqIndex;
pub use layout::{ChannelLayout, Partitioning};
pub use shard::Shard;
