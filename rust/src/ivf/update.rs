//! Dynamic knowledge-base updates: add and remove vectors without
//! retraining — the RALM selling point the paper's introduction leads
//! with ("knowledge editing can be achieved by simply updating the
//! database, without retraining the LLM").
//!
//! Adds assign the new vector to its nearest coarse centroid and append
//! its PQ code; removals tombstone by global id. Neither touches the
//! trained coarse/PQ codebooks (the Faiss operating model).

use std::collections::HashSet;

use super::index::IvfPqIndex;
use crate::pq::kmeans::nearest;

impl IvfPqIndex {
    /// Insert one vector with a caller-chosen global id. Returns the IVF
    /// list it landed in.
    pub fn add(&mut self, id: u64, v: &[f32]) -> usize {
        assert_eq!(v.len(), self.d);
        let (l, _) = nearest(v, &self.centroids, self.nlist, self.d);
        let mut code = vec![0u8; self.m];
        self.pq.encode_one(v, &mut code);
        self.list_codes[l].extend_from_slice(&code);
        self.list_ids[l].push(id);
        l
    }

    /// Insert a batch of (id, vector) pairs.
    pub fn add_batch(&mut self, ids: &[u64], data: &[f32]) {
        assert_eq!(data.len(), ids.len() * self.d);
        for (i, &id) in ids.iter().enumerate() {
            self.add(id, &data[i * self.d..(i + 1) * self.d]);
        }
    }

    /// Remove every vector whose id is in `ids`. Returns how many entries
    /// were removed. O(total vectors) — batched removal is the intended
    /// usage pattern (knowledge deletions are rare, bulk events).
    pub fn remove(&mut self, ids: &HashSet<u64>) -> usize {
        let mut removed = 0;
        let m = self.m;
        for l in 0..self.nlist {
            let keep: Vec<usize> = (0..self.list_ids[l].len())
                .filter(|&j| !ids.contains(&self.list_ids[l][j]))
                .collect();
            if keep.len() == self.list_ids[l].len() {
                continue;
            }
            removed += self.list_ids[l].len() - keep.len();
            let mut new_codes = Vec::with_capacity(keep.len() * m);
            let mut new_ids = Vec::with_capacity(keep.len());
            for &j in &keep {
                new_codes.extend_from_slice(&self.list_codes[l][j * m..(j + 1) * m]);
                new_ids.push(self.list_ids[l][j]);
            }
            self.list_codes[l] = new_codes;
            self.list_ids[l] = new_ids;
        }
        removed
    }

    /// Replace the vector behind an id (delete + re-insert).
    pub fn update(&mut self, id: u64, v: &[f32]) {
        let mut one = HashSet::new();
        one.insert(id);
        self.remove(&one);
        self.add(id, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy() -> (IvfPqIndex, Vec<f32>, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (1500, 16, 4, 16);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 2), data, d)
    }

    #[test]
    fn added_vector_is_retrievable() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(5);
        let v = rng.normal_vec(d);
        idx.add(999_999, &v);
        // Searching with the vector itself must surface the new id.
        let (ids, _) = idx.search(&v, idx.nlist, 10);
        assert!(ids.contains(&999_999), "{ids:?}");
    }

    #[test]
    fn removed_vector_never_returned() {
        let (mut idx, data, d) = toy();
        let victim = 42u64;
        let before = idx.len();
        let mut ids = HashSet::new();
        ids.insert(victim);
        assert_eq!(idx.remove(&ids), 1);
        assert_eq!(idx.len(), before - 1);
        let q = &data[victim as usize * d..(victim as usize + 1) * d];
        let (got, _) = idx.search(q, idx.nlist, 50);
        assert!(!got.contains(&victim));
    }

    #[test]
    fn update_moves_vector() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(7);
        let v1 = rng.normal_vec(d);
        let v2: Vec<f32> = v1.iter().map(|x| x + 10.0).collect();
        idx.add(777_777, &v1);
        idx.update(777_777, &v2);
        // Still exactly one copy.
        let count: usize = idx
            .list_ids
            .iter()
            .flatten()
            .filter(|&&i| i == 777_777)
            .count();
        assert_eq!(count, 1);
        let (got, _) = idx.search(&v2, idx.nlist, 5);
        assert!(got.contains(&777_777));
    }

    #[test]
    fn batch_add_keeps_alignment() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(8);
        let new = rng.normal_vec(5 * d);
        idx.add_batch(&[9001, 9002, 9003, 9004, 9005], &new);
        for l in 0..idx.nlist {
            assert_eq!(idx.list_codes[l].len(), idx.list_ids[l].len() * idx.m);
        }
        assert_eq!(idx.len(), 1505);
    }

    #[test]
    fn remove_batch_counts() {
        let (mut idx, _, _) = toy();
        let ids: HashSet<u64> = (0..100u64).collect();
        assert_eq!(idx.remove(&ids), 100);
        assert_eq!(idx.remove(&ids), 0); // idempotent
    }
}
