//! Dynamic knowledge-base updates: add and remove vectors without
//! retraining — the RALM selling point the paper's introduction leads
//! with ("knowledge editing can be achieved by simply updating the
//! database, without retraining the LLM").
//!
//! Adds assign the new vector to its nearest coarse centroid and append
//! its PQ code; removals tombstone by global id. Neither touches the
//! trained coarse/PQ codebooks (the Faiss operating model).

use std::collections::HashSet;

use super::index::IvfPqIndex;
use crate::pq::kmeans::nearest;

impl IvfPqIndex {
    /// Insert one vector with a caller-chosen global id. Returns the IVF
    /// list it landed in.
    pub fn add(&mut self, id: u64, v: &[f32]) -> usize {
        assert_eq!(v.len(), self.d);
        let (l, _) = nearest(v, &self.centroids, self.nlist, self.d);
        let mut code = vec![0u8; self.m];
        self.pq.encode_one(v, &mut code);
        self.list_codes[l].extend_from_slice(&code);
        self.list_ids[l].push(id);
        l
    }

    /// Insert a batch of (id, vector) pairs.
    pub fn add_batch(&mut self, ids: &[u64], data: &[f32]) {
        assert_eq!(data.len(), ids.len() * self.d);
        for (i, &id) in ids.iter().enumerate() {
            self.add(id, &data[i * self.d..(i + 1) * self.d]);
        }
    }

    /// Remove every vector whose id is in `ids`. Returns how many entries
    /// were removed. O(total vectors) — batched removal is the intended
    /// usage pattern (knowledge deletions are rare, bulk events).
    pub fn remove(&mut self, ids: &HashSet<u64>) -> usize {
        let mut removed = 0;
        let m = self.m;
        for l in 0..self.nlist {
            let keep: Vec<usize> = (0..self.list_ids[l].len())
                .filter(|&j| !ids.contains(&self.list_ids[l][j]))
                .collect();
            if keep.len() == self.list_ids[l].len() {
                continue;
            }
            removed += self.list_ids[l].len() - keep.len();
            let mut new_codes = Vec::with_capacity(keep.len() * m);
            let mut new_ids = Vec::with_capacity(keep.len());
            for &j in &keep {
                new_codes.extend_from_slice(&self.list_codes[l][j * m..(j + 1) * m]);
                new_ids.push(self.list_ids[l][j]);
            }
            self.list_codes[l] = new_codes;
            self.list_ids[l] = new_ids;
        }
        removed
    }

    /// Replace the vector behind an id (delete + re-insert).
    pub fn update(&mut self, id: u64, v: &[f32]) {
        let mut one = HashSet::new();
        one.insert(id);
        self.remove(&one);
        self.add(id, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy() -> (IvfPqIndex, Vec<f32>, usize) {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (1500, 16, 4, 16);
        let data = rng.normal_vec(n * d);
        (IvfPqIndex::build(&data, n, d, m, nlist, 2), data, d)
    }

    #[test]
    fn added_vector_is_retrievable() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(5);
        let v = rng.normal_vec(d);
        idx.add(999_999, &v);
        // Searching with the vector itself must surface the new id.
        let (ids, _) = idx.search(&v, idx.nlist, 10);
        assert!(ids.contains(&999_999), "{ids:?}");
    }

    #[test]
    fn removed_vector_never_returned() {
        let (mut idx, data, d) = toy();
        let victim = 42u64;
        let before = idx.len();
        let mut ids = HashSet::new();
        ids.insert(victim);
        assert_eq!(idx.remove(&ids), 1);
        assert_eq!(idx.len(), before - 1);
        let q = &data[victim as usize * d..(victim as usize + 1) * d];
        let (got, _) = idx.search(q, idx.nlist, 50);
        assert!(!got.contains(&victim));
    }

    #[test]
    fn update_moves_vector() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(7);
        let v1 = rng.normal_vec(d);
        let v2: Vec<f32> = v1.iter().map(|x| x + 10.0).collect();
        idx.add(777_777, &v1);
        idx.update(777_777, &v2);
        // Still exactly one copy.
        let count: usize = idx
            .list_ids
            .iter()
            .flatten()
            .filter(|&&i| i == 777_777)
            .count();
        assert_eq!(count, 1);
        let (got, _) = idx.search(&v2, idx.nlist, 5);
        assert!(got.contains(&777_777));
    }

    #[test]
    fn batch_add_keeps_alignment() {
        let (mut idx, _, d) = toy();
        let mut rng = Rng::new(8);
        let new = rng.normal_vec(5 * d);
        idx.add_batch(&[9001, 9002, 9003, 9004, 9005], &new);
        for l in 0..idx.nlist {
            assert_eq!(idx.list_codes[l].len(), idx.list_ids[l].len() * idx.m);
        }
        assert_eq!(idx.len(), 1505);
    }

    #[test]
    fn remove_batch_counts() {
        let (mut idx, _, _) = toy();
        let ids: HashSet<u64> = (0..100u64).collect();
        assert_eq!(idx.remove(&ids), 100);
        assert_eq!(idx.remove(&ids), 0); // idempotent
    }

    /// Apply a mixed add/remove/update history to an index.
    fn apply_history(idx: &mut IvfPqIndex, d: usize) -> Vec<(u64, Vec<f32>)> {
        let mut rng = Rng::new(77);
        let mut live_new: Vec<(u64, Vec<f32>)> = Vec::new();
        for i in 0..20u64 {
            let v = rng.normal_vec(d);
            idx.add(100_000 + i, &v);
            live_new.push((100_000 + i, v));
        }
        let victims: HashSet<u64> = (0..50u64).collect();
        idx.remove(&victims);
        for (id, v) in live_new.iter_mut().take(5) {
            let moved: Vec<f32> = v.iter().map(|x| x + 3.0).collect();
            idx.update(*id, &moved);
            *v = moved;
        }
        live_new
    }

    #[test]
    fn updates_match_fresh_encoding_reference() {
        // Pin add/remove/update against the reference behaviour under the
        // *same trained codebooks*: every live inserted vector must sit in
        // the list `nearest` assigns it, carrying exactly the code
        // `pq.encode_one` produces — i.e. updates are indistinguishable
        // from having encoded the vector fresh at build time.
        let (mut idx, _, d) = toy();
        let live_new = apply_history(&mut idx, d);
        for (id, v) in &live_new {
            let (want_list, _) = nearest(v, &idx.centroids, idx.nlist, idx.d);
            let mut want_code = vec![0u8; idx.m];
            idx.pq.encode_one(v, &mut want_code);
            let mut found = 0usize;
            for l in 0..idx.nlist {
                for (j, &lid) in idx.list_ids[l].iter().enumerate() {
                    if lid == *id {
                        found += 1;
                        assert_eq!(l, want_list, "id {id} in wrong list");
                        assert_eq!(
                            &idx.list_codes[l][j * idx.m..(j + 1) * idx.m],
                            &want_code[..],
                            "id {id} carries a stale code"
                        );
                    }
                }
            }
            assert_eq!(found, 1, "id {id} must appear exactly once");
        }
        // Removed ids are gone everywhere.
        for l in 0..idx.nlist {
            assert!(idx.list_ids[l].iter().all(|&i| i >= 50));
            assert_eq!(idx.list_codes[l].len(), idx.list_ids[l].len() * idx.m);
        }
    }

    #[test]
    fn carve_of_updated_index_yields_consistent_flat_extents() {
        // Rebalancing re-carves live (updated) indexes: the flat Shard
        // layout must stay consistent — extents tile the buffers exactly,
        // shards partition the index, and per-list round-robin
        // interleaving reconstructs each updated list verbatim.
        use crate::ivf::shard::Shard;
        let (mut idx, _, d) = toy();
        apply_history(&mut idx, d);
        for n_shards in [1usize, 2, 3] {
            let shards: Vec<Shard> =
                (0..n_shards).map(|s| Shard::carve(&idx, s, n_shards)).collect();
            let total: usize = shards.iter().map(Shard::len).sum();
            assert_eq!(total, idx.len(), "shards must partition the index");
            for sh in &shards {
                assert_eq!(sh.n_lists(), idx.nlist);
                assert_eq!(sh.codes.len(), sh.ids.len() * sh.m);
                let mut cursor = 0usize;
                for (l, &(off, len)) in sh.extents.iter().enumerate() {
                    assert_eq!(off, cursor, "extent gap at list {l}");
                    cursor += len;
                }
                assert_eq!(cursor, sh.len(), "extents must tile the buffers");
            }
            // Round-robin reconstruction: vector j of list l lives at
            // shard (j % n_shards), in list order.
            for l in 0..idx.nlist {
                let mut cursors = vec![0usize; n_shards];
                for (j, &want_id) in idx.list_ids[l].iter().enumerate() {
                    let s = j % n_shards;
                    let ids = shards[s].list_ids(l);
                    let codes = shards[s].list_codes(l);
                    let c = cursors[s];
                    assert_eq!(ids[c], want_id, "list {l} row {j}");
                    assert_eq!(
                        &codes[c * idx.m..(c + 1) * idx.m],
                        &idx.list_codes[l][j * idx.m..(j + 1) * idx.m],
                        "list {l} row {j} codes"
                    );
                    cursors[s] += 1;
                }
                for (s, &c) in cursors.iter().enumerate() {
                    assert_eq!(c, shards[s].list_len(l), "shard {s} list {l} len");
                }
            }
        }
    }
}
