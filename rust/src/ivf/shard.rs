//! A memory-node shard of the database: the slice of every IVF list that
//! one disaggregated node holds under vector-sharded partitioning
//! (paper Sec 4.3, first scheme).
//!
//! Storage is flat and list-major: one contiguous codes buffer, one
//! contiguous ids buffer, and per-list `(offset, len)` extents. A scan
//! reads each probed list *in place* through [`list_codes`](Shard::list_codes)
//! / [`list_ids`](Shard::list_ids) — the per-query gather copy of the old
//! per-list `Vec<Vec<u8>>` layout is gone (EXPERIMENTS.md §Perf).

use super::index::IvfPqIndex;

/// One node's shard: flat codes + ids with per-list extents.
pub struct Shard {
    pub node_id: usize,
    pub n_nodes: usize,
    pub m: usize,
    /// All PQ codes, list-contiguous: list `l` occupies
    /// `codes[off * m .. (off + len) * m]` for `(off, len) = extents[l]`.
    pub codes: Vec<u8>,
    /// Global vector ids, aligned row-for-row with `codes`.
    pub ids: Vec<u64>,
    /// Per-list `(offset, len)` in vectors into `codes`/`ids`.
    pub extents: Vec<(usize, usize)>,
}

impl Shard {
    /// Carve node `node_id`'s vector-sharded slice out of a built index.
    /// Vector `j` of list `l` goes to node `j % n_nodes` (round-robin, so
    /// shard sizes differ by at most one vector per list).
    pub fn carve(index: &IvfPqIndex, node_id: usize, n_nodes: usize) -> Shard {
        assert!(node_id < n_nodes);
        let m = index.m;
        let approx = index.len() / n_nodes + index.nlist;
        let mut codes = Vec::with_capacity(approx * m);
        let mut ids = Vec::with_capacity(approx);
        let mut extents = Vec::with_capacity(index.nlist);
        for l in 0..index.nlist {
            let lids = &index.list_ids[l];
            let lcodes = &index.list_codes[l];
            let off = ids.len();
            for (j, &id) in lids.iter().enumerate() {
                if j % n_nodes == node_id {
                    codes.extend_from_slice(&lcodes[j * m..(j + 1) * m]);
                    ids.push(id);
                }
            }
            extents.push((off, ids.len() - off));
        }
        Shard { node_id, n_nodes, m, codes, ids, extents }
    }

    /// Number of IVF lists this shard spans.
    pub fn n_lists(&self) -> usize {
        self.extents.len()
    }

    /// Vectors held for one list.
    pub fn list_len(&self, l: usize) -> usize {
        self.extents[l].1
    }

    /// One list's PQ codes, in place (no copy).
    pub fn list_codes(&self, l: usize) -> &[u8] {
        let (off, len) = self.extents[l];
        &self.codes[off * self.m..(off + len) * self.m]
    }

    /// One list's global vector ids, in place (no copy).
    pub fn list_ids(&self, l: usize) -> &[u64] {
        let (off, len) = self.extents[l];
        &self.ids[off..off + len]
    }

    /// Vectors this shard scans for a probe set.
    pub fn scan_count(&self, lists: &[u32]) -> usize {
        lists.iter().map(|&l| self.extents[l as usize].1).sum()
    }

    /// Total vectors held.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy() -> IvfPqIndex {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (2000, 16, 4, 32);
        let data = rng.normal_vec(n * d);
        IvfPqIndex::build(&data, n, d, m, nlist, 3)
    }

    #[test]
    fn shards_partition_exactly() {
        let idx = toy();
        let shards: Vec<Shard> = (0..4).map(|i| Shard::carve(&idx, i, 4)).collect();
        let total: usize = shards.iter().map(Shard::len).sum();
        assert_eq!(total, idx.len());
        // Every id appears in exactly one shard.
        let mut all: Vec<u64> =
            shards.iter().flat_map(|s| s.ids.iter().cloned()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), idx.len());
    }

    #[test]
    fn shard_loads_balanced_per_list() {
        let idx = toy();
        let shards: Vec<Shard> = (0..4).map(|i| Shard::carve(&idx, i, 4)).collect();
        for l in 0..idx.nlist {
            let sizes: Vec<usize> = shards.iter().map(|s| s.list_len(l)).collect();
            let max = sizes.iter().max().unwrap();
            let min = sizes.iter().min().unwrap();
            assert!(max - min <= 1, "list {l}: {sizes:?}");
        }
    }

    #[test]
    fn flat_layout_is_contiguous_and_aligned() {
        let idx = toy();
        let s = Shard::carve(&idx, 0, 2);
        assert_eq!(s.n_lists(), idx.nlist);
        assert_eq!(s.codes.len(), s.ids.len() * s.m);
        // Extents tile the flat buffers exactly, in list order.
        let mut cursor = 0usize;
        for l in 0..s.n_lists() {
            let (off, len) = s.extents[l];
            assert_eq!(off, cursor, "list {l} extent not contiguous");
            cursor += len;
            assert_eq!(s.list_codes(l).len(), len * s.m);
            assert_eq!(s.list_ids(l).len(), len);
        }
        assert_eq!(cursor, s.len());
    }

    #[test]
    fn in_place_slices_match_index_lists() {
        // A 1-node shard's per-list views must equal the index's own
        // per-list storage — the in-place scan sees exactly what the old
        // gather copy produced.
        let idx = toy();
        let s = Shard::carve(&idx, 0, 1);
        for l in 0..idx.nlist {
            assert_eq!(s.list_codes(l), &idx.list_codes[l][..], "codes, list {l}");
            assert_eq!(s.list_ids(l), &idx.list_ids[l][..], "ids, list {l}");
        }
        let lists = [0u32, 3, 7];
        assert_eq!(s.scan_count(&lists), idx.scan_count(&lists));
    }

    #[test]
    fn single_node_shard_is_whole_index() {
        let idx = toy();
        let s = Shard::carve(&idx, 0, 1);
        assert_eq!(s.len(), idx.len());
    }
}
