//! A memory-node shard of the database: the slice of every IVF list that
//! one disaggregated node holds under vector-sharded partitioning
//! (paper Sec 4.3, first scheme).

use super::index::IvfPqIndex;

/// One node's shard: per-list codes + global ids.
pub struct Shard {
    pub node_id: usize,
    pub n_nodes: usize,
    pub m: usize,
    pub list_codes: Vec<Vec<u8>>,
    pub list_ids: Vec<Vec<u64>>,
}

impl Shard {
    /// Carve node `node_id`'s vector-sharded slice out of a built index.
    /// Vector `j` of list `l` goes to node `j % n_nodes` (round-robin, so
    /// shard sizes differ by at most one vector per list).
    pub fn carve(index: &IvfPqIndex, node_id: usize, n_nodes: usize) -> Shard {
        assert!(node_id < n_nodes);
        let m = index.m;
        let mut list_codes = Vec::with_capacity(index.nlist);
        let mut list_ids = Vec::with_capacity(index.nlist);
        for l in 0..index.nlist {
            let ids = &index.list_ids[l];
            let codes = &index.list_codes[l];
            let mut sc = Vec::new();
            let mut si = Vec::new();
            for (j, &id) in ids.iter().enumerate() {
                if j % n_nodes == node_id {
                    sc.extend_from_slice(&codes[j * m..(j + 1) * m]);
                    si.push(id);
                }
            }
            list_codes.push(sc);
            list_ids.push(si);
        }
        Shard { node_id, n_nodes, m, list_codes, list_ids }
    }

    /// Vectors this shard scans for a probe set.
    pub fn scan_count(&self, lists: &[u32]) -> usize {
        lists.iter().map(|&l| self.list_ids[l as usize].len()).sum()
    }

    /// Total vectors held.
    pub fn len(&self) -> usize {
        self.list_ids.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Gather the (codes, global ids) of a probe set into contiguous
    /// buffers — the staging step before either the native ADC scan or the
    /// PJRT accelerator artifact.
    pub fn gather(&self, lists: &[u32]) -> (Vec<u8>, Vec<u64>) {
        let total = self.scan_count(lists);
        let mut codes = Vec::with_capacity(total * self.m);
        let mut ids = Vec::with_capacity(total);
        for &l in lists {
            codes.extend_from_slice(&self.list_codes[l as usize]);
            ids.extend_from_slice(&self.list_ids[l as usize]);
        }
        (codes, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn toy() -> IvfPqIndex {
        let mut rng = Rng::new(1);
        let (n, d, m, nlist) = (2000, 16, 4, 32);
        let data = rng.normal_vec(n * d);
        IvfPqIndex::build(&data, n, d, m, nlist, 3)
    }

    #[test]
    fn shards_partition_exactly() {
        let idx = toy();
        let shards: Vec<Shard> = (0..4).map(|i| Shard::carve(&idx, i, 4)).collect();
        let total: usize = shards.iter().map(Shard::len).sum();
        assert_eq!(total, idx.len());
        // Every id appears in exactly one shard.
        let mut all: Vec<u64> =
            shards.iter().flat_map(|s| s.list_ids.iter().flatten().cloned()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), idx.len());
    }

    #[test]
    fn shard_loads_balanced_per_list() {
        let idx = toy();
        let shards: Vec<Shard> = (0..4).map(|i| Shard::carve(&idx, i, 4)).collect();
        for l in 0..idx.nlist {
            let sizes: Vec<usize> =
                shards.iter().map(|s| s.list_ids[l].len()).collect();
            let max = sizes.iter().max().unwrap();
            let min = sizes.iter().min().unwrap();
            assert!(max - min <= 1, "list {l}: {sizes:?}");
        }
    }

    #[test]
    fn gather_aligns_codes_and_ids() {
        let idx = toy();
        let s = Shard::carve(&idx, 0, 2);
        let lists = [0u32, 3, 7];
        let (codes, ids) = s.gather(&lists);
        assert_eq!(codes.len(), ids.len() * s.m);
        assert_eq!(ids.len(), s.scan_count(&lists));
    }

    #[test]
    fn single_node_shard_is_whole_index() {
        let idx = toy();
        let s = Shard::carve(&idx, 0, 1);
        assert_eq!(s.len(), idx.len());
    }
}
