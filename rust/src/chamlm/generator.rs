//! Token-generation driver: the per-sequence loop of the paper's workflow
//! (Sec 3), alternating decode steps with retrievals at the model's
//! interval and recording per-step latency for Fig 11.

use std::time::Instant;

use anyhow::Result;

use super::sampler::Sampler;
use super::worker::GpuWorker;
use crate::coordinator::retriever::Retriever;
use crate::util::rng::Rng;

/// Per-sequence generation statistics.
#[derive(Clone, Debug, Default)]
pub struct GenerationStats {
    pub tokens: Vec<u32>,
    /// Wall-clock seconds per step (measured host execution).
    pub step_measured_s: Vec<f64>,
    /// Modeled per-step latency (GPU decode model + retrieval model) —
    /// the paper-scale Fig 11 series.
    pub step_modeled_s: Vec<f64>,
    /// Which steps performed retrieval.
    pub retrieval_steps: Vec<usize>,
}

impl GenerationStats {
    pub fn modeled_total(&self) -> f64 {
        self.step_modeled_s.iter().sum()
    }

    pub fn measured_total(&self) -> f64 {
        self.step_measured_s.iter().sum()
    }
}

/// Drives one worker + one retriever to generate sequences.
pub struct Generator<'a> {
    pub worker: &'a mut GpuWorker,
    /// Speculation slot (= the worker's GPU id): this sequence's prefetch
    /// lane on the dispatcher, isolated from other GPU streams.
    pub slot: usize,
    pub retriever: &'a mut Retriever,
    pub sampler: Sampler,
    /// Modeled per-decode-step latency of the paper-scale model this
    /// scaled execution stands in for (set by the caller from GpuModel).
    pub modeled_decode_s: f64,
    pub modeled_encode_s: f64,
}

impl<'a> Generator<'a> {
    /// Generate `n_tokens` starting from `prompt_token`.
    pub fn generate(
        &mut self,
        prompt_token: u32,
        n_tokens: usize,
        seed: u64,
    ) -> Result<GenerationStats> {
        let mut rng = Rng::new(seed);
        let mut stats = GenerationStats::default();
        self.worker.reset();
        let interval = self.worker.model.interval.max(1);
        let is_encdec = self.worker.model.is_encdec();

        let mut token = prompt_token;
        // Retrieval payload carried between steps (decoder-only).
        let mut payload: (Vec<u32>, Vec<f32>) = (Vec::new(), Vec::new());
        // The first query comes from the prompt embedding; we bootstrap
        // with a zero query replaced after the first step.
        let mut query: Vec<f32> = Vec::new();

        for step in 0..n_tokens {
            let t0 = Instant::now();
            let mut modeled = self.modeled_decode_s;

            let do_retrieve = step % interval == 0 && (!query.is_empty() || step > 0 || !is_encdec);
            if do_retrieve {
                let q = if query.is_empty() {
                    // Bootstrap query: zero vector (first step only).
                    vec![0.0f32; self.retriever.dim()]
                } else {
                    project_query(&query, self.retriever.dim())
                };
                let r = if self.retriever.retcache_enabled() {
                    // Cache-aware path: a hit charges the lookup constant,
                    // a verified speculative prefetch only the residual
                    // not hidden behind the decode window since the
                    // previous retrieval (max(decode, retrieval) instead
                    // of the sum), a miss the full round trip.
                    let cr = self.retriever.retrieve_cached_from(self.slot, &q)?;
                    modeled +=
                        self.retriever.charge_retrieval(&cr, self.modeled_decode_s, interval);
                    cr.result
                } else {
                    let r = self.retriever.retrieve(&q)?;
                    modeled += r.modeled_s;
                    r
                };
                stats.retrieval_steps.push(step);
                if is_encdec {
                    let chunks = self.retriever.gather_chunks(&r.ids);
                    let want = self.worker.enc_tokens();
                    let mut toks = chunks;
                    toks.resize(want, 0);
                    self.worker.encode(&toks)?;
                    modeled += self.modeled_encode_s;
                } else {
                    payload = (self.retriever.gather_next_tokens(&r.ids), r.dists);
                }
            }

            let out = self.worker.step(token, (&payload.0, &payload.1))?;
            token = self.sampler.sample(&out.probs, &mut rng);
            query = out.query_vec;

            stats.tokens.push(token);
            stats.step_measured_s.push(t0.elapsed().as_secs_f64());
            stats.step_modeled_s.push(modeled);
        }
        Ok(stats)
    }
}

/// Map the model's hidden-state query to the retriever's vector dimension
/// (tile or truncate — the paper's models emit queries already in database
/// dimension; the scaled models differ, so we adapt deterministically).
pub fn project_query(hidden: &[f32], d: usize) -> Vec<f32> {
    let mut q = Vec::with_capacity(d);
    while q.len() < d {
        let take = (d - q.len()).min(hidden.len());
        q.extend_from_slice(&hidden[..take]);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn project_query_tiles() {
        let h = vec![1.0, 2.0];
        assert_eq!(project_query(&h, 5), vec![1.0, 2.0, 1.0, 2.0, 1.0]);
        assert_eq!(project_query(&h, 1), vec![1.0]);
    }

    #[test]
    fn stats_totals() {
        let s = GenerationStats {
            tokens: vec![1, 2],
            step_measured_s: vec![0.1, 0.2],
            step_modeled_s: vec![0.3, 0.4],
            retrieval_steps: vec![0],
        };
        assert!((s.measured_total() - 0.3).abs() < 1e-12);
        assert!((s.modeled_total() - 0.7).abs() < 1e-12);
    }
}
