//! Token sampling over the decode artifact's probability output.

use crate::util::rng::Rng;

/// Sampling policy for the next token.
#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    /// Argmax decoding.
    Greedy,
    /// Temperature sampling (1.0 = raw distribution).
    Temperature(f32),
    /// Top-k truncation + temperature.
    TopK(usize, f32),
}

impl Sampler {
    /// Draw a token id from `probs` (already a normalized distribution —
    /// the decode artifact outputs post-interpolation probabilities).
    pub fn sample(&self, probs: &[f32], rng: &mut Rng) -> u32 {
        match *self {
            Sampler::Greedy => argmax(probs),
            Sampler::Temperature(t) => {
                if t <= 1e-4 {
                    return argmax(probs);
                }
                let weights: Vec<f64> =
                    probs.iter().map(|&p| (p.max(1e-30) as f64).powf(1.0 / t as f64)).collect();
                draw(&weights, rng)
            }
            Sampler::TopK(k, t) => {
                let mut idx: Vec<usize> = (0..probs.len()).collect();
                idx.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
                idx.truncate(k.max(1));
                let weights: Vec<f64> = idx
                    .iter()
                    .map(|&i| (probs[i].max(1e-30) as f64).powf(1.0 / t.max(1e-4) as f64))
                    .collect();
                idx[draw(&weights, rng) as usize] as u32
            }
        }
    }
}

fn argmax(probs: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &p) in probs.iter().enumerate() {
        if p > probs[best] {
            best = i;
        }
    }
    best as u32
}

fn draw(weights: &[f64], rng: &mut Rng) -> u32 {
    let total: f64 = weights.iter().sum();
    let mut target = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        target -= w;
        if target <= 0.0 {
            return i as u32;
        }
    }
    (weights.len() - 1) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let probs = [0.1, 0.6, 0.3];
        let mut rng = Rng::new(1);
        assert_eq!(Sampler::Greedy.sample(&probs, &mut rng), 1);
    }

    #[test]
    fn temperature_zero_is_greedy() {
        let probs = [0.2, 0.1, 0.7];
        let mut rng = Rng::new(2);
        assert_eq!(Sampler::Temperature(0.0).sample(&probs, &mut rng), 2);
    }

    #[test]
    fn sampling_respects_distribution() {
        let probs = [0.9, 0.1];
        let mut rng = Rng::new(3);
        let s = Sampler::Temperature(1.0);
        let n = 10_000;
        let ones =
            (0..n).filter(|_| s.sample(&probs, &mut rng) == 1).count() as f64 / n as f64;
        assert!((ones - 0.1).abs() < 0.02, "{ones}");
    }

    #[test]
    fn topk_excludes_tail() {
        let probs = [0.5, 0.3, 0.2];
        let mut rng = Rng::new(4);
        let s = Sampler::TopK(2, 1.0);
        for _ in 0..200 {
            assert_ne!(s.sample(&probs, &mut rng), 2);
        }
    }
}
