//! ChamLM: the multi-accelerator LLM inference engine (paper Sec 3/5).
//!
//! Each [`worker::GpuWorker`] owns one compiled decode artifact (the
//! stand-in for one GPU process) with parameters and KV cache resident as
//! PJRT buffers; [`generator::Generator`] drives token generation with
//! retrieval at the model's interval, and [`pool::WorkerPool`] fans
//! requests across workers like the paper's per-GPU processes.

pub mod batch_worker;
pub mod generator;
pub mod pool;
pub mod sampler;
pub mod scheduler;
pub mod worker;

pub use batch_worker::BatchWorker;

pub use generator::{GenerationStats, Generator};
pub use pool::WorkerPool;
pub use scheduler::{ContinuousScheduler, Request};
pub use worker::GpuWorker;
