//! A ChamLM worker: the rust stand-in for one of the paper's GPU
//! processes. Owns a compiled decode artifact, its parameters and the KV
//! cache as device-resident PJRT buffers, and steps one token at a time.

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::runtime::{Executor, HostTensor, Runtime};

/// Decode-step output on the host.
pub struct StepOutput {
    /// Post-interpolation next-token distribution (vocab,).
    pub probs: Vec<f32>,
    /// The retrieval query vector for the next step (dim,).
    pub query_vec: Vec<f32>,
}

/// One model replica driving an AOT decode artifact.
///
/// The KV cache round-trips through the host between steps: this
/// xla_extension returns multi-output executables as one tuple buffer, so
/// buffer-level state feedback is not available (see Executor::call). For
/// the scaled models the copy is ~16 MB/step, well under the decode cost.
pub struct GpuWorker {
    pub id: usize,
    pub model: &'static ModelConfig,
    decode: Executor,
    encode: Option<Executor>,
    /// KV cache threaded through decode calls (host side).
    kv: Option<HostTensor>,
    /// Encoder output for cross-attention (EncDec models).
    enc_out: Option<HostTensor>,
    pub knn_k: usize,
    pub vocab: usize,
    pub steps: u64,
}

impl GpuWorker {
    /// Create a worker for a model whose decode artifact exists.
    pub fn new(
        runtime: &Runtime,
        model: &'static ModelConfig,
        id: usize,
        seed: u64,
    ) -> Result<GpuWorker> {
        let artifact = model
            .artifact
            .with_context(|| format!("model {} has no decode artifact", model.name))?;
        let decode = runtime.executor(artifact, seed)?;
        let knn_k = decode.spec.static_usize("knn_k").unwrap_or(model.k);
        let vocab = decode.spec.static_usize("vocab").unwrap_or(model.vocab);
        let encode = if model.is_encdec() {
            Some(runtime.executor(&format!("encode_{}", model.name), seed)?)
        } else {
            None
        };
        Ok(GpuWorker {
            id,
            model,
            decode,
            encode,
            kv: None,
            enc_out: None,
            knn_k,
            vocab,
            steps: 0,
        })
    }

    /// Reset per-sequence state (KV cache re-zeroed lazily on next step).
    pub fn reset(&mut self) {
        self.kv = None;
        self.enc_out = None;
        self.steps = 0;
    }

    fn kv_meta_shape(&self) -> Vec<usize> {
        // Inputs: params..., token, pos, kv_cache, rt, rd [, enc_out]
        self.decode
            .spec
            .args()
            .find(|a| a.name == "kv_cache")
            .expect("decode artifact missing kv_cache input")
            .shape
            .to_vec()
    }

    /// Run the encoder over retrieved chunk tokens (EncDec only).
    pub fn encode(&mut self, chunk_tokens: &[u32]) -> Result<()> {
        let enc = self.encode.as_ref().context("not an encoder-decoder model")?;
        let meta = enc.spec.args().next().unwrap().clone();
        anyhow::ensure!(
            chunk_tokens.len() == meta.element_count(),
            "encoder expects {} tokens, got {}",
            meta.element_count(),
            chunk_tokens.len()
        );
        let toks: Vec<i32> = chunk_tokens.iter().map(|&t| t as i32).collect();
        let outs = enc.call(&[HostTensor::i32(&meta.shape, toks)])?;
        self.enc_out = Some(outs.into_iter().next().unwrap());
        Ok(())
    }

    /// One decode step: feed the current token + retrieval payload, get
    /// the next-token distribution and the next retrieval query.
    ///
    /// `retrieved`: (token ids, distances) of the K neighbors — for
    /// decoder-only models this is the kNN-LM payload; EncDec models
    /// ignore it (pass empty) and consume `enc_out` set via [`encode`].
    pub fn step(
        &mut self,
        token: u32,
        retrieved: (&[u32], &[f32]),
    ) -> Result<StepOutput> {
        let pos = self.steps as i32;
        let max_seq = self.model.max_seq as i32;
        anyhow::ensure!(pos < max_seq, "sequence exceeds max_seq {max_seq}");

        // Assemble args in manifest order: token, pos, kv, then enc_out
        // (EncDec) or the kNN payload rt, rd (decoder-only).
        let kv = match self.kv.take() {
            Some(t) => t,
            None => {
                let shape = self.kv_meta_shape();
                HostTensor::F32 {
                    shape: shape.clone(),
                    data: vec![0.0; shape.iter().product()],
                }
            }
        };
        let mut args = vec![
            HostTensor::i32(&[1], vec![token as i32]),
            HostTensor::i32(&[1], vec![pos]),
            kv,
        ];
        if self.model.is_encdec() {
            let enc = self
                .enc_out
                .as_ref()
                .context("EncDec worker stepped before encode()")?;
            args.push(enc.clone());
        } else {
            let (rt, rd) = self.retrieval_payload(retrieved);
            args.push(rt);
            args.push(rd);
        }

        let mut outs = self.decode.call(&args)?;
        // Outputs: probs, query_vec, new_kv.
        anyhow::ensure!(outs.len() == 3, "decode expects 3 outputs");
        self.kv = Some(outs.pop().unwrap());
        let query_vec = outs.pop().unwrap().as_f32()?.to_vec();
        let probs = outs.pop().unwrap().as_f32()?.to_vec();
        self.steps += 1;
        Ok(StepOutput { probs, query_vec })
    }

    fn retrieval_payload(&self, retrieved: (&[u32], &[f32])) -> (HostTensor, HostTensor) {
        let (ids, dists) = retrieved;
        let k = self.knn_k;
        // Missing neighbors get the model's clip ceiling (1e4): far enough
        // for ~zero weight, small enough to stay finite through softmax.
        let mut rt = vec![0i32; k];
        let mut rd = vec![1e4f32; k];
        for i in 0..k.min(ids.len()) {
            rt[i] = ids[i] as i32;
            rd[i] = dists.get(i).copied().unwrap_or(1e4);
        }
        (HostTensor::i32(&[k], rt), HostTensor::f32(&[k], rd))
    }

    /// Expected retrieved-chunk token count for encode() (EncDec).
    pub fn enc_tokens(&self) -> usize {
        self.encode
            .as_ref()
            .map(|e| e.spec.args().next().unwrap().element_count())
            .unwrap_or(0)
    }

    /// Sanity check a probability vector (used by integration tests).
    pub fn check_probs(probs: &[f32]) -> bool {
        let sum: f32 = probs.iter().sum();
        probs.iter().all(|p| p.is_finite() && *p >= -1e-6) && (sum - 1.0).abs() < 1e-2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_probs_rejects_garbage() {
        assert!(GpuWorker::check_probs(&[0.5, 0.5]));
        assert!(!GpuWorker::check_probs(&[f32::NAN, 1.0]));
        assert!(!GpuWorker::check_probs(&[0.9, 0.9]));
    }
}
