//! Batched decode worker: drives the vmapped `decode_*_b{B}` artifact —
//! one PJRT call advances B sequences one token (the Fig 12 throughput
//! configuration, where batching amortizes the weight traffic).

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::runtime::{Executor, HostTensor, Runtime};

/// B sequences stepped in lockstep through one batched artifact.
pub struct BatchWorker {
    pub model: &'static ModelConfig,
    pub batch: usize,
    decode: Executor,
    kv: Option<HostTensor>,
    pub knn_k: usize,
    pub steps: u64,
}

/// One batched step's host outputs.
pub struct BatchStepOutput {
    /// (B, vocab) row-major probabilities.
    pub probs: Vec<f32>,
    /// (B, dim) row-major retrieval queries.
    pub query_vecs: Vec<f32>,
    pub vocab: usize,
    pub dim: usize,
}

impl BatchStepOutput {
    pub fn probs_of(&self, b: usize) -> &[f32] {
        &self.probs[b * self.vocab..(b + 1) * self.vocab]
    }

    pub fn query_of(&self, b: usize) -> &[f32] {
        &self.query_vecs[b * self.dim..(b + 1) * self.dim]
    }
}

impl BatchWorker {
    /// Load `decode_<model>_b<batch>` (must exist in the manifest).
    pub fn new(
        runtime: &Runtime,
        model: &'static ModelConfig,
        batch: usize,
        seed: u64,
    ) -> Result<BatchWorker> {
        let name = format!("decode_{}_b{batch}", model.name);
        let decode = runtime
            .executor(&name, seed)
            .with_context(|| format!("loading batched artifact {name}"))?;
        let knn_k = decode.spec.static_usize("knn_k").unwrap_or(model.k);
        Ok(BatchWorker { model, batch, decode, kv: None, knn_k, steps: 0 })
    }

    pub fn reset(&mut self) {
        self.kv = None;
        self.steps = 0;
    }

    /// Advance all B sequences one token.
    ///
    /// `tokens`: B current tokens. `retrieved`: per-sequence (ids, dists)
    /// payloads (empty slices allowed).
    pub fn step(
        &mut self,
        tokens: &[u32],
        retrieved: &[(Vec<u32>, Vec<f32>)],
    ) -> Result<BatchStepOutput> {
        let b = self.batch;
        anyhow::ensure!(tokens.len() == b, "expected {b} tokens");
        anyhow::ensure!(retrieved.len() == b, "expected {b} payloads");
        let pos = self.steps as i32;
        anyhow::ensure!((pos as usize) < self.model.max_seq, "sequence overflow");

        let kv = match self.kv.take() {
            Some(t) => t,
            None => {
                let shape = self
                    .decode
                    .spec
                    .args()
                    .find(|a| a.name == "kv_cache")
                    .context("missing kv_cache input")?
                    .shape
                    .clone();
                HostTensor::F32 {
                    shape: shape.clone(),
                    data: vec![0.0; shape.iter().product()],
                }
            }
        };
        let k = self.knn_k;
        let mut rt = vec![0i32; b * k];
        let mut rd = vec![1e4f32; b * k];
        for (s, (ids, dists)) in retrieved.iter().enumerate() {
            for i in 0..k.min(ids.len()) {
                rt[s * k + i] = ids[i] as i32;
                rd[s * k + i] = dists.get(i).copied().unwrap_or(1e4);
            }
        }
        let args = vec![
            HostTensor::i32(&[b, 1], tokens.iter().map(|&t| t as i32).collect()),
            HostTensor::i32(&[b, 1], vec![pos; b]),
            kv,
            HostTensor::i32(&[b, k], rt),
            HostTensor::f32(&[b, k], rd),
        ];
        let mut outs = self.decode.call(&args)?;
        anyhow::ensure!(outs.len() == 3, "decode expects 3 outputs");
        self.kv = Some(outs.pop().unwrap());
        let query_vecs = outs.pop().unwrap().as_f32()?.to_vec();
        let probs = outs.pop().unwrap().as_f32()?.to_vec();
        self.steps += 1;
        Ok(BatchStepOutput {
            probs,
            query_vecs,
            vocab: self.model.vocab,
            dim: self.model.dim,
        })
    }
}
