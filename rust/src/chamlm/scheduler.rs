//! Continuous-batching scheduler for ChamLM (paper Sec 6.3: "early
//! termination for a subset of sequences can be easily addressed via
//! preemptive scheduling", citing vLLM). Sequence slots admit/evict
//! requests between decode steps so the batch stays full; the modeled
//! throughput feeds the Fig 12 ablation.

use std::collections::VecDeque;

/// One generation request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt_token: u32,
    pub max_tokens: usize,
    /// Optional early stop token (EOS).
    pub stop_token: Option<u32>,
}

/// State of an admitted sequence.
#[derive(Clone, Debug)]
pub struct SeqSlot {
    pub request: Request,
    pub generated: usize,
    pub last_token: u32,
    pub done: bool,
}

/// Scheduler outcome for one step.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Slot indices participating in this decode step.
    pub active: Vec<usize>,
    /// Requests admitted this step (slot indices).
    pub admitted: Vec<usize>,
    /// Requests completed last step and evicted now (request ids).
    pub completed: Vec<u64>,
}

/// Fixed-capacity continuous batcher.
pub struct ContinuousScheduler {
    pub capacity: usize,
    slots: Vec<Option<SeqSlot>>,
    queue: VecDeque<Request>,
    pub total_completed: u64,
}

impl ContinuousScheduler {
    pub fn new(capacity: usize) -> Self {
        ContinuousScheduler {
            capacity,
            slots: (0..capacity).map(|_| None).collect(),
            queue: VecDeque::new(),
            total_completed: 0,
        }
    }

    /// Enqueue an incoming request.
    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn slot(&self, i: usize) -> Option<&SeqSlot> {
        self.slots[i].as_ref()
    }

    /// Plan the next step: evict finished sequences, admit queued ones
    /// into free slots, return the active set.
    pub fn plan_step(&mut self) -> StepPlan {
        let mut plan = StepPlan::default();
        // Evict completions.
        for i in 0..self.capacity {
            let done = self.slots[i].as_ref().map(|s| s.done).unwrap_or(false);
            if done {
                let s = self.slots[i].take().unwrap();
                plan.completed.push(s.request.id);
                self.total_completed += 1;
            }
        }
        // Admit from the queue.
        for i in 0..self.capacity {
            if self.slots[i].is_none() {
                if let Some(req) = self.queue.pop_front() {
                    let t = req.prompt_token;
                    self.slots[i] = Some(SeqSlot {
                        request: req,
                        generated: 0,
                        last_token: t,
                        done: false,
                    });
                    plan.admitted.push(i);
                } else {
                    break;
                }
            }
        }
        plan.active = (0..self.capacity).filter(|&i| self.slots[i].is_some()).collect();
        plan
    }

    /// Record the token produced for slot `i` this step and update its
    /// completion state.
    pub fn record_token(&mut self, i: usize, token: u32) {
        let slot = self.slots[i].as_mut().expect("record on empty slot");
        slot.generated += 1;
        slot.last_token = token;
        let hit_stop = slot.request.stop_token == Some(token);
        if slot.generated >= slot.request.max_tokens || hit_stop {
            slot.done = true;
        }
    }

    /// True when no work remains anywhere.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }
}

/// Modeled throughput comparison: continuous vs static batching for a
/// workload of variable-length sequences (the Fig 12 batching ablation).
/// Returns (static_steps, continuous_steps) to finish the workload on a
/// batch of `capacity` with per-step cost independent of occupancy.
pub fn batching_ablation(lengths: &[usize], capacity: usize) -> (usize, usize) {
    // Static: sequences grouped into waves; each wave runs to its longest.
    let mut static_steps = 0;
    for wave in lengths.chunks(capacity) {
        static_steps += wave.iter().max().copied().unwrap_or(0);
    }
    // Continuous: slots refill immediately; total steps = makespan of a
    // greedy packing, simulated exactly.
    let mut sched = ContinuousScheduler::new(capacity);
    for (i, &len) in lengths.iter().enumerate() {
        sched.submit(Request {
            id: i as u64,
            prompt_token: 0,
            max_tokens: len,
            stop_token: None,
        });
    }
    let mut continuous_steps = 0;
    loop {
        let plan = sched.plan_step();
        if plan.active.is_empty() {
            break;
        }
        for &i in &plan.active {
            sched.record_token(i, 1);
        }
        continuous_steps += 1;
        assert!(continuous_steps < 10_000_000, "runaway");
    }
    (static_steps, continuous_steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, len: usize) -> Request {
        Request { id, prompt_token: 1, max_tokens: len, stop_token: None }
    }

    #[test]
    fn admits_up_to_capacity() {
        let mut s = ContinuousScheduler::new(2);
        for i in 0..5 {
            s.submit(req(i, 4));
        }
        let plan = s.plan_step();
        assert_eq!(plan.active.len(), 2);
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn completion_frees_slot_for_next_request() {
        let mut s = ContinuousScheduler::new(1);
        s.submit(req(1, 2));
        s.submit(req(2, 1));
        let p1 = s.plan_step();
        assert_eq!(p1.admitted, vec![0]);
        s.record_token(0, 9);
        let p2 = s.plan_step(); // seq 1 not done yet
        assert!(p2.completed.is_empty());
        s.record_token(0, 9);
        let p3 = s.plan_step(); // seq 1 done, seq 2 admitted
        assert_eq!(p3.completed, vec![1]);
        assert_eq!(p3.admitted, vec![0]);
        assert_eq!(s.slot(0).unwrap().request.id, 2);
    }

    #[test]
    fn stop_token_terminates_early() {
        let mut s = ContinuousScheduler::new(1);
        s.submit(Request { id: 7, prompt_token: 0, max_tokens: 100, stop_token: Some(3) });
        s.plan_step();
        s.record_token(0, 5);
        assert!(!s.slot(0).unwrap().done);
        s.record_token(0, 3);
        assert!(s.slot(0).unwrap().done);
    }

    #[test]
    fn drains_to_idle() {
        let mut s = ContinuousScheduler::new(3);
        for i in 0..7 {
            s.submit(req(i, 1 + (i as usize % 3)));
        }
        let mut steps = 0;
        loop {
            let plan = s.plan_step();
            if plan.active.is_empty() {
                break;
            }
            for &i in &plan.active {
                s.record_token(i, 1);
            }
            steps += 1;
            assert!(steps < 100);
        }
        assert!(s.idle());
        assert_eq!(s.total_completed, 7);
    }

    #[test]
    fn continuous_beats_static_on_skewed_lengths() {
        // One long sequence per wave stalls static batching.
        let lengths: Vec<usize> =
            (0..32).map(|i| if i % 8 == 0 { 100 } else { 10 }).collect();
        let (stat, cont) = batching_ablation(&lengths, 8);
        assert!(cont < stat, "continuous {cont} !< static {stat}");
        // And no worse than the theoretical floor: total_tokens/capacity.
        let floor = lengths.iter().sum::<usize>() / 8;
        assert!(cont >= floor);
    }

    #[test]
    fn equal_lengths_tie() {
        let lengths = vec![16usize; 16];
        let (stat, cont) = batching_ablation(&lengths, 4);
        assert_eq!(stat, cont);
    }
}
