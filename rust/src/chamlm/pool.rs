//! A pool of ChamLM workers — the paper's "each GPU is managed by an
//! independent GPU process" (Sec 3), with round-robin sequence assignment
//! used by the throughput experiments (Fig 12).

use anyhow::Result;

use super::worker::GpuWorker;
use crate::config::ModelConfig;
use crate::runtime::Runtime;

/// A set of model replicas.
pub struct WorkerPool {
    pub workers: Vec<GpuWorker>,
    next: usize,
}

impl WorkerPool {
    /// Spin up `n` workers over the same artifact (parameters shared by
    /// seed, mirroring "a copy of the entire LLM per GPU").
    pub fn new(
        runtime: &Runtime,
        model: &'static ModelConfig,
        n: usize,
        seed: u64,
    ) -> Result<WorkerPool> {
        let workers = (0..n)
            .map(|i| GpuWorker::new(runtime, model, i, seed))
            .collect::<Result<Vec<_>>>()?;
        Ok(WorkerPool { workers, next: 0 })
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Round-robin checkout of the next worker.
    pub fn next_worker(&mut self) -> &mut GpuWorker {
        let i = self.next;
        self.next = (self.next + 1) % self.workers.len();
        &mut self.workers[i]
    }
}

#[cfg(test)]
mod tests {
    // WorkerPool needs a live runtime + artifacts; covered by the
    // integration tests in rust/tests/integration.rs. The round-robin
    // policy is trivially exercised there.
}
