//! Front-door QoS and framing robustness tests: the slow-writer
//! regression (a frame dribbled over many read timeouts must decode, not
//! desync), tenant isolation (a flooding batch tenant sheds via explicit
//! `Backpressure` instead of starving interactive traffic), shutdown
//! gating (only the first/admin connection may stop the server), and the
//! thread-per-connection A/B baseline staying bit-identical.

use std::io::Write as _;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::admission::{
    QosConfig, TenantPolicy, BATCH_TENANT_BASE,
};
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{
    CoordinatorClient, CoordinatorServer, Reply, ServeMode,
};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::protocol::{
    Backpressure, Frame, Kind, RetrieveRequest, RetrieveResponse,
};
use chameleon::trace::Tracer;

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 2000, 32, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let corpus = Corpus::generate(2000, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, 10), corpus)
}

fn queries(seed: u64) -> SyntheticDataset {
    SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        32,
        seed,
    )
}

/// Trickle one `RetrieveRequest` frame over a raw socket in three chunks
/// with 150 ms pauses — each pause is longer than the server's 100 ms
/// read timeout, and the total spans > 3x of it. The reply must be the
/// correct retrieval result, and a second request on the same connection
/// must still work (no desync, no disconnect).
fn dribble_roundtrip(addr: std::net::SocketAddr, seed: u64) {
    let ds = queries(seed);
    let mut local = build_retriever(seed);
    let q = ds.query(0);
    let want = local.retrieve(q).unwrap();
    let want_tokens = local.gather_next_tokens(&want.ids);

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let bytes = RetrieveRequest {
        query_id: 0,
        gpu_id: 0,
        query: q.to_vec(),
        lists: vec![],
        k: 10,
        want_chunks: false,
        deadline_us: 0,
    }
    .encode()
    .to_bytes();
    // Split mid-header (10 < 16) and then mid-payload.
    let cuts = [10usize, bytes.len() / 2, bytes.len()];
    let mut start = 0;
    for &end in &cuts {
        stream.write_all(&bytes[start..end]).unwrap();
        stream.flush().unwrap();
        start = end;
        if end < bytes.len() {
            std::thread::sleep(Duration::from_millis(150));
        }
    }
    let f = Frame::read_from(&mut stream).unwrap();
    assert_eq!(f.kind, Kind::RetrieveResponse, "dribbled frame desynced");
    let resp = RetrieveResponse::decode(&f).unwrap();
    assert_eq!(resp.query_id, 0);
    assert_eq!(resp.tokens, want_tokens, "dribbled request got wrong reply");
    assert_eq!(resp.dists, want.dists);

    // The connection must survive the slow frame: a normal follow-up
    // request round-trips on the same stream.
    let q1 = ds.query(1);
    let want1 = local.retrieve(q1).unwrap();
    let want_tokens1 = local.gather_next_tokens(&want1.ids);
    RetrieveRequest {
        query_id: 1,
        gpu_id: 0,
        query: q1.to_vec(),
        lists: vec![],
        k: 10,
        want_chunks: false,
        deadline_us: 0,
    }
    .encode()
    .write_to(&mut stream)
    .unwrap();
    let f1 = Frame::read_from(&mut stream).unwrap();
    let resp1 = RetrieveResponse::decode(&f1).unwrap();
    assert_eq!(resp1.query_id, 1);
    assert_eq!(resp1.tokens, want_tokens1, "follow-up after dribble broken");
}

#[test]
fn slow_writer_dribble_event_loop() {
    let mut server = CoordinatorServer::spawn(
        || build_retriever(51),
        ServeMode::Concurrent(BatchPolicy::default()),
    )
    .unwrap();
    dribble_roundtrip(server.addr, 51);
    server.shutdown();
}

#[test]
fn slow_writer_dribble_sequential() {
    let mut server = CoordinatorServer::spawn_sequential(|| build_retriever(52)).unwrap();
    dribble_roundtrip(server.addr, 52);
    server.shutdown();
}

#[test]
fn slow_writer_dribble_threaded() {
    let mut server = CoordinatorServer::spawn(
        || build_retriever(53),
        ServeMode::Threaded(BatchPolicy::default()),
    )
    .unwrap();
    dribble_roundtrip(server.addr, 53);
    server.shutdown();
}

/// A batch tenant flooding the server must shed via explicit
/// `Backpressure` frames (never lost requests), and interactive latency
/// must stay within 2x of its unloaded p99 (plus scheduling grace).
#[test]
fn flooding_batch_tenant_cannot_starve_interactive() {
    let base = QosConfig::default();
    let qos = QosConfig {
        // Tiny batch queue so the flood sheds quickly.
        batch: TenantPolicy::unlimited_rate(4),
        ..base
    };
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) };
    let mut server = CoordinatorServer::spawn_qos(
        || build_retriever(61),
        ServeMode::Concurrent(policy),
        qos,
        Tracer::off(),
    )
    .unwrap();
    let addr = server.addr;
    let stats = server.stats();
    let ds = queries(61);

    // Unloaded interactive baseline.
    let mut interactive = CoordinatorClient::connect(addr, 0).unwrap();
    let mut unloaded = Vec::new();
    for i in 0..20 {
        let t0 = Instant::now();
        interactive.retrieve(ds.query(i % 32), &[], 10, false).unwrap();
        unloaded.push(t0.elapsed());
    }
    unloaded.sort();
    let unloaded_p99 = *unloaded.last().unwrap();

    // Flood from the batch tenant while interactive keeps its cadence.
    // Bursts are pipelined raw frames — a blocking client could never
    // overfill its own queue — then exactly one reply per request is
    // collected (Backpressure frames arrive out of FIFO order).
    let flood = std::thread::spawn(move || {
        let ds = queries(61);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let (mut sent, mut served, mut shed) = (0usize, 0usize, 0usize);
        for burst in 0..25u64 {
            for i in 0..16u64 {
                RetrieveRequest {
                    query_id: burst * 16 + i,
                    gpu_id: BATCH_TENANT_BASE,
                    query: ds.query(i as usize % 32).to_vec(),
                    lists: vec![],
                    k: 10,
                    want_chunks: false,
                    deadline_us: 0,
                }
                .encode()
                .write_to(&mut stream)
                .unwrap();
                sent += 1;
            }
            for _ in 0..16 {
                let f = Frame::read_from(&mut stream).unwrap();
                match f.kind {
                    Kind::RetrieveResponse => served += 1,
                    Kind::Backpressure => {
                        let bp = Backpressure::decode(&f).unwrap();
                        assert_eq!(bp.tenant, BATCH_TENANT_BASE);
                        assert!(bp.reason == 1 || bp.reason == 2);
                        shed += 1;
                    }
                    other => panic!("unexpected reply frame {other:?}"),
                }
            }
        }
        (sent, served, shed)
    });

    let mut loaded = Vec::new();
    for i in 0..40 {
        let t0 = Instant::now();
        match interactive.try_retrieve(ds.query(i % 32), &[], 10, false).unwrap() {
            Reply::Response(_) => {}
            Reply::Backpressure(bp) => {
                panic!("interactive request shed under batch flood: {bp:?}")
            }
        }
        loaded.push(t0.elapsed());
    }
    loaded.sort();
    let loaded_p99 = loaded[loaded.len() * 99 / 100];

    let (sent, served, shed) = flood.join().unwrap();
    // Conservation: every flooded request was answered or explicitly
    // shed — nothing silently dropped.
    assert_eq!(served + shed, sent, "flooder lost replies");
    assert!(shed >= 1, "flood never saw Backpressure (queue_cap 4, bursts of 16)");
    assert_eq!(stats.shed(), shed as u64);

    // Isolation: interactive latency bounded despite the flood. The
    // floor absorbs scheduler noise on loaded CI machines.
    let bound = (unloaded_p99 * 2).max(Duration::from_millis(250));
    assert!(
        loaded_p99 <= bound,
        "interactive starved: loaded p99 {loaded_p99:?} vs unloaded {unloaded_p99:?}"
    );
    server.shutdown();
}

/// Only the first (admin) connection may stop the server: a later
/// client's Shutdown frame is counted and ignored, and service
/// continues; the admin's Shutdown actually stops the front door.
#[test]
fn shutdown_gated_to_admin_connection() {
    let mut server = CoordinatorServer::spawn(
        || build_retriever(71),
        ServeMode::Concurrent(BatchPolicy::default()),
    )
    .unwrap();
    let addr = server.addr;
    let stats = server.stats();
    let ds = queries(71);

    // conn 0 is the admin; connect it first and prove it works.
    let mut admin = CoordinatorClient::connect(addr, 0).unwrap();
    admin.retrieve(ds.query(0), &[], 10, false).unwrap();

    // A second tenant's Shutdown must be denied.
    let mut rogue = CoordinatorClient::connect(addr, 1).unwrap();
    rogue.retrieve(ds.query(1), &[], 10, false).unwrap();
    rogue.shutdown_coordinator();
    let t0 = Instant::now();
    while stats.shutdown_denied() == 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(stats.shutdown_denied() >= 1, "rogue shutdown not recorded");

    // The server still serves existing and new connections.
    rogue.retrieve(ds.query(2), &[], 10, false).unwrap();
    let mut late = CoordinatorClient::connect(addr, 2).unwrap();
    late.retrieve(ds.query(3), &[], 10, false).unwrap();

    // The admin's Shutdown goes through: new connections are refused
    // once the accept loop exits.
    admin.shutdown_coordinator();
    let t0 = Instant::now();
    let mut stopped = false;
    while t0.elapsed() < Duration::from_secs(10) {
        match TcpStream::connect(addr) {
            Err(_) => {
                stopped = true;
                break;
            }
            Ok(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    assert!(stopped, "admin shutdown did not stop the accept loop");
    server.shutdown();
}

/// A/B baseline: the thread-per-connection mode must produce
/// bit-identical results to in-process serving, pipelined.
#[test]
fn threaded_baseline_matches_reference() {
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(20) };
    let mut server = CoordinatorServer::spawn(
        || build_retriever(81),
        ServeMode::Threaded(policy),
    )
    .unwrap();
    let addr = server.addr;
    let ds = queries(81);
    let mut local = build_retriever(81);

    let got: Vec<(usize, Vec<RetrieveResponse>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2usize)
            .map(|c| {
                let ds = &ds;
                s.spawn(move || {
                    let mut client =
                        CoordinatorClient::connect(addr, c as u32).unwrap();
                    let window: Vec<&[f32]> =
                        (0..4).map(|i| ds.query(c * 4 + i)).collect();
                    (c, client.retrieve_pipelined(&window, 10, false).unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (c, resps) in got {
        assert_eq!(resps.len(), 4);
        for (i, r) in resps.iter().enumerate() {
            let want = local.retrieve(ds.query(c * 4 + i)).unwrap();
            assert_eq!(r.tokens, local.gather_next_tokens(&want.ids), "c{c} q{i}");
            assert_eq!(r.dists, want.dists, "c{c} q{i}");
        }
    }
    server.shutdown();
}
