//! Integration tests for the concurrent coordinator serving core: N
//! concurrent GPU clients against one coordinator must get results
//! bit-identical to sequential in-process serving, with cross-connection
//! dynamic batching actually observed (at least one dispatched batch of
//! size >= 2), FIFO reply order per connection, and speculation-slot
//! teardown when a connection departs.

use std::time::Duration;

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer, ServeMode};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::protocol::RetrieveResponse;

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 4;

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 2000, 32, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let corpus = Corpus::generate(2000, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, 10), corpus)
}

fn queries(seed: u64) -> SyntheticDataset {
    SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        32,
        seed,
    )
}

#[test]
fn concurrent_clients_match_sequential_and_batches_form() {
    let policy = BatchPolicy {
        max_batch: CLIENTS,
        // Generous window: the test must observe batching even on a
        // loaded CI box, and pipelined windows fill it immediately.
        max_wait: Duration::from_millis(50),
    };
    let mut server =
        CoordinatorServer::spawn(|| build_retriever(21), ServeMode::Concurrent(policy))
            .unwrap();
    let addr = server.addr;
    let stats = server.stats();
    let ds = queries(21);

    // Reference: the identical retrieval stack, served sequentially
    // in-process — the concurrent server must be bit-identical.
    let mut local = build_retriever(21);
    let mut want: Vec<Vec<(Vec<u32>, Vec<f32>)>> = Vec::new(); // [client][query]
    for c in 0..CLIENTS {
        let mut per_client = Vec::new();
        for i in 0..PER_CLIENT {
            let q = ds.query(c * PER_CLIENT + i);
            let r = local.retrieve(q).unwrap();
            per_client.push((local.gather_next_tokens(&r.ids), r.dists));
        }
        want.push(per_client);
    }

    // N concurrent clients, each pipelining its whole window: replies are
    // FIFO per connection and the shared batcher sees real batches.
    let got: Vec<(usize, Vec<RetrieveResponse>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let ds = &ds;
                s.spawn(move || {
                    let mut client =
                        CoordinatorClient::connect(addr, c as u32).unwrap();
                    let window: Vec<&[f32]> = (0..PER_CLIENT)
                        .map(|i| ds.query(c * PER_CLIENT + i))
                        .collect();
                    let resp =
                        client.retrieve_pipelined(&window, 10, false).unwrap();
                    (c, resp)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (c, resps) in got {
        assert_eq!(resps.len(), PER_CLIENT, "client {c}");
        for (i, r) in resps.iter().enumerate() {
            let (want_tokens, want_dists) = &want[c][i];
            assert_eq!(&r.tokens, want_tokens, "client {c} query {i} tokens");
            assert_eq!(&r.dists, want_dists, "client {c} query {i} dists");
        }
    }
    assert_eq!(stats.requests(), (CLIENTS * PER_CLIENT) as u64);
    assert!(
        stats.max_batch() >= 2,
        "batching not observed: max dispatched batch {}",
        stats.max_batch()
    );
    server.shutdown();
}

#[test]
fn sequential_mode_still_serves_and_never_batches() {
    let mut server = CoordinatorServer::spawn_sequential(|| build_retriever(33)).unwrap();
    let ds = queries(33);
    let mut local = build_retriever(33);
    for gpu in 0..2u32 {
        let mut client = CoordinatorClient::connect(server.addr, gpu).unwrap();
        let q = ds.query(gpu as usize);
        let want = local.retrieve(q).unwrap();
        let want_tokens = local.gather_next_tokens(&want.ids);
        let resp = client.retrieve(q, &[], 10, false).unwrap();
        assert_eq!(resp.tokens, want_tokens, "gpu {gpu}");
        assert_eq!(resp.dists, want.dists, "gpu {gpu}");
        drop(client);
    }
    let stats = server.stats();
    assert_eq!(stats.requests(), 2);
    assert_eq!(stats.max_batch(), 1, "sequential mode must not batch");
    server.shutdown();
}

#[test]
fn disconnect_triggers_speculation_slot_teardown() {
    use chameleon::retcache::SpecConfig;
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) };
    let mut server = CoordinatorServer::spawn(
        || {
            let mut r = build_retriever(44);
            r.enable_speculation(SpecConfig::default());
            r
        },
        ServeMode::Concurrent(policy),
    )
    .unwrap();
    let stats = server.stats();
    let ds = queries(44);
    {
        let mut client = CoordinatorClient::connect(server.addr, 3).unwrap();
        // Misses issue speculative prefetches on this connection's slot.
        client.retrieve(ds.query(0), &[], 10, false).unwrap();
        client.retrieve(ds.query(1), &[], 10, false).unwrap();
    } // dropped: the reader exits and queues the teardown
    let t0 = std::time::Instant::now();
    while stats.teardowns() == 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        stats.teardowns() >= 1,
        "connection teardown (slot cancellation) never processed"
    );
    server.shutdown();
}
