//! Correctness harness for the zero-copy list-major scan pipeline.
//!
//! Property: for random indexes and any node count in 1..=8, the
//! gather-free fused scan+select path — single-query and list-major
//! batched, in both [`SelectMode`]s (the hierarchical queue in its exact
//! configuration) — reproduces the flat-scan reference's distance bits
//! rank by rank, and a batched round is bit-identical (ids included) to
//! the single-query path. On a single node in exact mode the fused
//! selector's `(dist, gather-order)` key pins the *full* stable-sort
//! order, ids and all. (Across nodes, which member of an equal-distance
//! tie group survives the k boundary is representation-defined — PQ code
//! collisions make tie groups real — so the cross-node pin is on
//! distance bits.)
//!
//! Also pins the satellite rewrites: partial-selection `probe` and the
//! fused `IvfPqIndex::search` against their full-sort references.

use chameleon::chamvs::dispatcher::{BatchQuery, Dispatcher};
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::kselect::{FusedSelector, HierarchicalConfig, SelectMode};
use chameleon::pq::scan::{
    adc_scan, adc_scan_scalar_into, build_lut, scan_list_into_sink, FUSED_TILE,
};
use chameleon::util::rng::Rng;

struct Universe {
    idx: IvfPqIndex,
    d: usize,
    k: usize,
    nprobe: usize,
}

fn random_universe(rng: &mut Rng) -> Universe {
    let m = [4usize, 8][rng.below(2)];
    let dsub = [2usize, 4][rng.below(2)];
    let d = m * dsub;
    let n = 400 + rng.below(500);
    let nlist = 8 + rng.below(17);
    let data = rng.normal_vec(n * d);
    let idx = IvfPqIndex::build(&data, n, d, m, nlist, rng.next_u64());
    let k = 1 + rng.below(16);
    let nprobe = 1 + rng.below(nlist);
    Universe { idx, d, k, nprobe }
}

fn build_nodes(
    idx: &IvfPqIndex,
    n_nodes: usize,
    k: usize,
    select: SelectMode,
) -> Vec<MemoryNode> {
    (0..n_nodes)
        .map(|i| {
            let mut node =
                MemoryNode::new(Shard::carve(idx, i, n_nodes), ScanEngine::Native, k);
            node.select = select;
            // Exact queues so the hierarchical mode is strictly checkable.
            node.kcfg = HierarchicalConfig::exact(k, node.kcfg.num_lanes);
            node
        })
        .collect()
}

/// Flat-scan reference: ADC over every probed list in probe order, stable
/// sort by distance, truncate to k — the ground truth both select modes
/// must reproduce.
fn flat_scan_reference(
    idx: &IvfPqIndex,
    query: &[f32],
    lists: &[u32],
    k: usize,
) -> Vec<(f32, u64)> {
    let lut = build_lut(&idx.pq, query);
    let mut all: Vec<(f32, u64)> = Vec::new();
    for &l in lists {
        let codes = &idx.list_codes[l as usize];
        let ids = &idx.list_ids[l as usize];
        let dists = adc_scan(codes, ids.len(), idx.m, &lut);
        for (i, &d) in dists.iter().enumerate() {
            all.push((d, ids[i]));
        }
    }
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    all.truncate(k);
    all
}

/// Distance bits must match rank by rank (the exact-selection multiset is
/// unique even where tie-group membership at the k boundary is not).
fn assert_dist_bits(got: &[(f32, u64)], want: &[(f32, u64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (rank, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.0.to_bits(),
            w.0.to_bits(),
            "{ctx}: distance bits at rank {rank}: {} vs {}",
            g.0,
            w.0
        );
    }
}

/// The property body for one node count: for both select modes, the
/// gather-free single-query scan and the list-major batched round
/// reproduce the flat-scan reference, and batched == single bit-for-bit
/// (ids included) within a mode.
fn check_pipeline(n_nodes: usize, cases: usize, base_seed: u64) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let u = random_universe(&mut rng);
        let queries: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(u.d)).collect();
        let lists: Vec<Vec<u32>> =
            queries.iter().map(|q| u.idx.probe(q, u.nprobe)).collect();
        for select in [SelectMode::Exact, SelectMode::Hierarchical] {
            let ctx = format!("nodes={n_nodes} seed={seed} {select:?}");
            let mut disp =
                Dispatcher::new(build_nodes(&u.idx, n_nodes, u.k, select), u.k);
            disp.n_threads = [0usize, 1, 2][rng.below(3)];

            let mut singles = Vec::new();
            for (q, l) in queries.iter().zip(&lists) {
                let got = disp.search(q, &u.idx.pq.centroids, l, u.nprobe).unwrap();
                let want = flat_scan_reference(&u.idx, q, l, u.k);
                assert_dist_bits(&got.topk, &want, &format!("{ctx} single"));
                assert_eq!(got.n_scanned, u.idx.scan_count(l), "{ctx}");
                singles.push(got.topk);
            }

            // List-major batched round: same bits as the single-query
            // path, ids included (the (dist, order) key pins ties even
            // though the round streams lists in a different order).
            let batch: Vec<BatchQuery> = queries
                .iter()
                .zip(&lists)
                .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
                .collect();
            let got_batch =
                disp.search_batch(&batch, &u.idx.pq.centroids, u.nprobe).unwrap();
            assert_eq!(got_batch.len(), queries.len());
            for (qi, (got, single)) in got_batch.iter().zip(&singles).enumerate() {
                assert_eq!(
                    &got.topk, single,
                    "{ctx} query {qi}: batched round must be bit-identical \
                     to the single-query scan"
                );
            }
        }
    }
}

#[test]
fn scan_pipeline_equivalence_1_node() {
    check_pipeline(1, 3, 0x5CA_0001);
}

#[test]
fn scan_pipeline_equivalence_2_nodes() {
    check_pipeline(2, 3, 0x5CA_0002);
}

#[test]
fn scan_pipeline_equivalence_4_nodes() {
    check_pipeline(4, 3, 0x5CA_0004);
}

#[test]
fn scan_pipeline_equivalence_8_nodes() {
    check_pipeline(8, 3, 0x5CA_0008);
}

/// On a single node in exact mode, the fused path reproduces the flat
/// reference's *ids* exactly, tie groups included: the `(dist, order)`
/// selection key is the stable-sort order.
#[test]
fn single_node_exact_mode_pins_full_order() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..4 {
        let u = random_universe(&mut rng);
        let mut disp =
            Dispatcher::new(build_nodes(&u.idx, 1, u.k, SelectMode::Exact), u.k);
        for _ in 0..3 {
            let q = rng.normal_vec(u.d);
            let l = u.idx.probe(&q, u.nprobe);
            let got = disp.search(&q, &u.idx.pq.centroids, &l, u.nprobe).unwrap();
            let want = flat_scan_reference(&u.idx, &q, &l, u.k);
            assert_eq!(got.topk.len(), want.len());
            for (g, w) in got.topk.iter().zip(&want) {
                assert_eq!(g.0.to_bits(), w.0.to_bits());
                assert_eq!(g.1, w.1, "ids must match in stable-sort order");
            }
        }
    }
}

/// SIMD pin (ISSUE 8): `scan_list_into_sink` + `FusedSelector` — which
/// route through the runtime-dispatched kernel set inside `adc_scan_into`
/// — reproduce a scalar flat scan + stable sort exactly at every paper
/// width: distance bits, ids, and tie order, across list lengths that
/// exercise empty lists, sub-lane tails, and tile boundaries.
#[test]
fn fused_sink_through_simd_kernels_matches_scalar_reference() {
    let mut rng = Rng::new(0x51D);
    let k = 40usize;
    for m in [16usize, 32, 64] {
        // Coarse LUT values force real distance ties across rows, so the
        // (dist, order) tie-break is actually exercised.
        let lut: Vec<f32> =
            (0..m * 256).map(|_| (rng.below(8) as f32) * 0.5).collect();
        let lens = [5usize, 0, FUSED_TILE + 33, 200, 7];

        let mut sel = FusedSelector::new(k);
        let mut scratch = Vec::new();
        let mut reference: Vec<(f32, u64, u64)> = Vec::new(); // (dist, order, id)
        let mut order_base = 0u64;
        let mut next_id = 0u64;
        for &len in &lens {
            let codes: Vec<u8> = (0..len * m).map(|_| rng.below(256) as u8).collect();
            let ids: Vec<u64> = (0..len as u64).map(|i| next_id + i).collect();
            next_id += len as u64;

            // Fused path: tiled scan through the active kernels into the
            // exact selector.
            scan_list_into_sink(&codes, m, &lut, &ids, order_base, &mut scratch, &mut sel);

            // Scalar reference: explicit scalar kernels, flat buffer.
            let mut dists = vec![0.0f32; len];
            adc_scan_scalar_into(&codes, len, m, &lut, &mut dists);
            for (i, &d) in dists.iter().enumerate() {
                reference.push((d, order_base + i as u64, ids[i]));
            }
            order_base += len as u64;
        }

        let mut got = Vec::new();
        sel.emit_into(&mut got);
        // Stable sort on (dist, order) — the fused selector's key.
        reference.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        reference.truncate(k);
        assert_eq!(got.len(), reference.len(), "m={m}: top-k length");
        for (rank, (g, w)) in got.iter().zip(&reference).enumerate() {
            assert_eq!(
                g.0.to_bits(),
                w.0.to_bits(),
                "m={m} rank {rank}: distance bits diverged from scalar"
            );
            assert_eq!(g.1, w.2, "m={m} rank {rank}: id/tie order diverged");
        }
    }
}

/// Satellite pin: the partial-selection probe returns exactly what the
/// old full-sort probe returned, in the same order.
#[test]
fn probe_partial_selection_matches_full_sort() {
    let mut rng = Rng::new(0xBEE);
    for _ in 0..5 {
        let u = random_universe(&mut rng);
        for _ in 0..4 {
            let q = rng.normal_vec(u.d);
            for nprobe in [0usize, 1, 3, u.idx.nlist / 2, u.idx.nlist, u.idx.nlist + 5]
            {
                let got = u.idx.probe(&q, nprobe);
                // Full-sort reference (the seed implementation).
                let mut dists: Vec<(f32, u32)> = (0..u.idx.nlist)
                    .map(|l| {
                        let c = &u.idx.centroids[l * u.d..(l + 1) * u.d];
                        let dist: f32 =
                            q.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                        (dist, l as u32)
                    })
                    .collect();
                dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                let want: Vec<u32> = dists[..nprobe.min(u.idx.nlist)]
                    .iter()
                    .map(|&(_, l)| l)
                    .collect();
                assert_eq!(got, want, "nprobe={nprobe}");
            }
        }
    }
}

/// Satellite pin: the fused `IvfPqIndex::search` is bit-identical (ids
/// and distance bits) to the seed's scan-all-then-full-sort pipeline.
#[test]
fn index_search_matches_full_sort_reference() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..5 {
        let u = random_universe(&mut rng);
        for _ in 0..4 {
            let q = rng.normal_vec(u.d);
            let (got_ids, got_d) = u.idx.search(&q, u.nprobe, u.k);
            let lists = u.idx.probe(&q, u.nprobe);
            let want = flat_scan_reference(&u.idx, &q, &lists, u.k);
            assert_eq!(got_ids.len(), want.len());
            for ((gi, gd), (wd, wi)) in got_ids
                .iter()
                .zip(&got_d)
                .zip(want.iter().map(|&(d, i)| (d, i)))
            {
                assert_eq!(gd.to_bits(), wd.to_bits());
                assert_eq!(*gi, wi, "search ids must keep stable-sort order");
            }
        }
    }
}
