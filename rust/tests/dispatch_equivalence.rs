//! Correctness harness for the parallel ChamVS dispatch path.
//!
//! Property: for random indexes and any node count in 1..=8, the
//! thread-pooled `Dispatcher::search` / `search_batch` top-K is
//! bit-identical (distance bits rank by rank; ids compared within
//! equal-distance tie groups, since PQ codes can collide) to a
//! single-threaded flat scan of the probed lists — and a speculative
//! `submit` -> `poll` returns exactly what the blocking `search` returns.
//!
//! Lifecycle: interleaved per-GPU `submit`/`poll`/`cancel` across slots
//! never leaks a `PendingScan`, never cross-delivers another slot's
//! ticket, and cancel-after-complete is a clean no-op.

use chameleon::chamvs::dispatcher::{BatchQuery, Dispatcher};
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::kselect::{HierarchicalConfig, SelectMode};
use chameleon::pq::scan::{adc_scan, build_lut};
use chameleon::util::rng::Rng;

/// One random test universe: a built index and its raw data dims.
struct Universe {
    idx: IvfPqIndex,
    d: usize,
    k: usize,
    nprobe: usize,
}

fn random_universe(rng: &mut Rng) -> Universe {
    let m = [4usize, 8][rng.below(2)];
    let dsub = [2usize, 4][rng.below(2)];
    let d = m * dsub;
    let n = 400 + rng.below(500);
    let nlist = 8 + rng.below(17);
    let data = rng.normal_vec(n * d);
    let idx = IvfPqIndex::build(&data, n, d, m, nlist, rng.next_u64());
    let k = 1 + rng.below(16);
    let nprobe = 1 + rng.below(nlist);
    Universe { idx, d, k, nprobe }
}

fn build_nodes(idx: &IvfPqIndex, n_nodes: usize, k: usize) -> Vec<MemoryNode> {
    (0..n_nodes)
        .map(|i| {
            let mut node =
                MemoryNode::new(Shard::carve(idx, i, n_nodes), ScanEngine::Native, k);
            // This suite pins the *hierarchical* selection path (in its
            // exact configuration) for strict equivalence checking; the
            // fused serving default is pinned by tests/scan_pipeline.rs.
            node.select = SelectMode::Hierarchical;
            node.kcfg = HierarchicalConfig::exact(k, node.kcfg.num_lanes);
            node
        })
        .collect()
}

/// Single-node flat-scan reference: ADC over every probed list with the
/// same LUT the dispatcher builds, globally sorted, truncated to k.
fn flat_scan_reference(idx: &IvfPqIndex, query: &[f32], lists: &[u32], k: usize) -> Vec<(f32, u64)> {
    let lut = build_lut(&idx.pq, query);
    let mut all: Vec<(f32, u64)> = Vec::new();
    for &l in lists {
        let codes = &idx.list_codes[l as usize];
        let ids = &idx.list_ids[l as usize];
        let dists = adc_scan(codes, ids.len(), idx.m, &lut);
        for (i, &d) in dists.iter().enumerate() {
            all.push((d, ids[i]));
        }
    }
    all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    all.truncate(k);
    all
}

/// Bit-identical comparison: distances must match bit-for-bit rank by
/// rank; ids must match within each equal-distance tie group (PQ-code
/// collisions make the order inside a tie group representation-defined).
fn assert_topk_equiv(got: &[(f32, u64)], want: &[(f32, u64)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (rank, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.0.to_bits(),
            w.0.to_bits(),
            "{ctx}: distance bits at rank {rank}: {} vs {}",
            g.0,
            w.0
        );
    }
    let mut i = 0;
    while i < got.len() {
        let mut j = i + 1;
        while j < got.len() && got[j].0.to_bits() == got[i].0.to_bits() {
            j += 1;
        }
        let mut gids: Vec<u64> = got[i..j].iter().map(|&(_, id)| id).collect();
        let mut wids: Vec<u64> = want[i..j].iter().map(|&(_, id)| id).collect();
        gids.sort_unstable();
        wids.sort_unstable();
        assert_eq!(gids, wids, "{ctx}: tie-group ids at ranks {i}..{j}");
        i = j;
    }
}

/// The property body for one node count: parallel search, batched search
/// and speculative submit->poll all reproduce the flat-scan reference.
fn check_equivalence(n_nodes: usize, cases: usize, base_seed: u64) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let u = random_universe(&mut rng);
        let mut disp = Dispatcher::new(build_nodes(&u.idx, n_nodes, u.k), u.k);
        // Random thread count (including the sequential baseline) — the
        // fan-out width must never change results.
        disp.n_threads = [0usize, 1, 2, 5][rng.below(4)];
        let ctx = format!("nodes={n_nodes} seed={seed}");

        // Parallel single-query search vs flat scan.
        let queries: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(u.d)).collect();
        let lists: Vec<Vec<u32>> =
            queries.iter().map(|q| u.idx.probe(q, u.nprobe)).collect();
        for (q, l) in queries.iter().zip(&lists) {
            let got = disp.search(q, &u.idx.pq.centroids, l, u.nprobe).unwrap();
            let want = flat_scan_reference(&u.idx, q, l, u.k);
            assert_topk_equiv(&got.topk, &want, &format!("{ctx} search"));
            assert!(got.measured_cpu_s >= got.measured_wall_s);
            assert_eq!(got.n_scanned, u.idx.scan_count(l));
        }

        // Batched dispatch vs the same references.
        let batch: Vec<BatchQuery> = queries
            .iter()
            .zip(&lists)
            .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
            .collect();
        let got_batch =
            disp.search_batch(&batch, &u.idx.pq.centroids, u.nprobe).unwrap();
        assert_eq!(got_batch.len(), queries.len());
        for ((q, l), got) in queries.iter().zip(&lists).zip(&got_batch) {
            let want = flat_scan_reference(&u.idx, q, l, u.k);
            assert_topk_equiv(&got.topk, &want, &format!("{ctx} search_batch"));
        }

        // Speculative submit -> poll == blocking search.
        let sq = rng.normal_vec(u.d);
        let sl = u.idx.probe(&sq, u.nprobe);
        let want = disp.search(&sq, &u.idx.pq.centroids, &sl, u.nprobe).unwrap();
        let t = disp.submit(&sq, &sl, u.nprobe);
        let got = disp.poll(t, &u.idx.pq.centroids).unwrap().unwrap();
        assert_topk_equiv(&got.topk, &want.topk, &format!("{ctx} submit/poll"));
        assert_eq!(disp.in_flight(), 0, "{ctx}: ticket leaked");
    }
}

#[test]
fn dispatch_equivalence_1_node() {
    check_equivalence(1, 4, 0xD15_0001);
}

#[test]
fn dispatch_equivalence_2_nodes() {
    check_equivalence(2, 4, 0xD15_0002);
}

#[test]
fn dispatch_equivalence_4_nodes() {
    check_equivalence(4, 4, 0xD15_0004);
}

#[test]
fn dispatch_equivalence_8_nodes() {
    check_equivalence(8, 4, 0xD15_0008);
}

/// Randomized interleaving of per-GPU submit/poll/cancel across four
/// slots, against a model of which tickets each slot owns. Every polled
/// result must match the blocking search for the query that slot
/// submitted (no cross-delivery), counts must never drift (no leaked
/// `PendingScan`), and cancel/poll after completion must be clean no-ops.
#[test]
fn slot_lifecycle_never_leaks_or_cross_delivers() {
    let mut rng = Rng::new(0x5107);
    let u = random_universe(&mut rng);
    let mut disp = Dispatcher::new(build_nodes(&u.idx, 4, u.k), u.k);

    const SLOTS: usize = 4;
    // Per-slot query (slot-distinct so cross-delivery is detectable) and
    // its expected blocking result.
    let queries: Vec<Vec<f32>> = (0..SLOTS).map(|_| rng.normal_vec(u.d)).collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| u.idx.probe(q, u.nprobe)).collect();
    let expected: Vec<Vec<(f32, u64)>> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| disp.search(q, &u.idx.pq.centroids, l, u.nprobe).unwrap().topk)
        .collect();

    // Model: the live tickets per slot.
    let mut live: Vec<Vec<chameleon::chamvs::Ticket>> = vec![Vec::new(); SLOTS];
    let mut collected: Vec<chameleon::chamvs::Ticket> = Vec::new();
    for step in 0..300 {
        let slot = rng.below(SLOTS);
        match rng.below(5) {
            // Submit on this slot's lane.
            0 | 1 => {
                let t = disp.submit_for(slot, &queries[slot], &lists[slot], u.nprobe);
                assert_eq!(disp.ticket_slot(t), Some(slot));
                live[slot].push(t);
            }
            // Poll one of this slot's tickets: the result must be the
            // slot's own query's result.
            2 => {
                if let Some(t) = live[slot].pop() {
                    let r = disp.poll(t, &u.idx.pq.centroids).unwrap().unwrap();
                    assert_topk_equiv(
                        &r.topk,
                        &expected[slot],
                        &format!("step {step} slot {slot}"),
                    );
                    collected.push(t);
                }
            }
            // Cancel one ticket.
            3 => {
                if let Some(t) = live[slot].pop() {
                    assert!(disp.cancel(t), "step {step}: live ticket must cancel");
                    collected.push(t);
                }
            }
            // Cancel the whole slot; occasionally run a batched round so
            // queued tickets get piggybacked into Done state first.
            _ => {
                if rng.below(2) == 0 {
                    let batch = [BatchQuery {
                        query: &queries[slot],
                        lists: &lists[slot],
                        trace_id: 0,
                    }];
                    disp.search_batch(&batch, &u.idx.pq.centroids, u.nprobe)
                        .unwrap();
                }
                let n = disp.cancel_slot(slot);
                assert_eq!(n, live[slot].len(), "step {step}: cancel_slot count");
                collected.extend(live[slot].drain(..));
            }
        }
        // No leaks, no cross-slot bleed: the dispatcher's per-slot counts
        // must track the model exactly.
        for (s, tickets) in live.iter().enumerate() {
            assert_eq!(
                disp.in_flight_for(s),
                tickets.len(),
                "step {step}: slot {s} count drift"
            );
        }
        assert_eq!(
            disp.in_flight(),
            live.iter().map(Vec::len).sum::<usize>(),
            "step {step}: total count drift"
        );
    }
    // Cancel/poll after completion are clean no-ops.
    for t in collected {
        assert!(!disp.cancel(t), "settled ticket must not cancel");
        assert!(disp.poll(t, &u.idx.pq.centroids).is_none());
    }
    // Drain what's left; the dispatcher must end empty.
    for (slot, tickets) in live.into_iter().enumerate() {
        for t in tickets {
            let r = disp.poll(t, &u.idx.pq.centroids).unwrap().unwrap();
            assert_topk_equiv(&r.topk, &expected[slot], &format!("drain slot {slot}"));
        }
    }
    assert_eq!(disp.in_flight(), 0);
}

/// A ticket left queued across multiple blocking rounds is executed once,
/// parked, and survives unrelated slots' cancellations.
#[test]
fn parked_results_survive_other_slot_teardown() {
    let mut rng = Rng::new(0x9A9);
    let u = random_universe(&mut rng);
    let mut disp = Dispatcher::new(build_nodes(&u.idx, 2, u.k), u.k);
    let q = rng.normal_vec(u.d);
    let l = u.idx.probe(&q, u.nprobe);
    let want = disp.search(&q, &u.idx.pq.centroids, &l, u.nprobe).unwrap();

    let t = disp.submit_for(7, &q, &l, u.nprobe);
    // Two batched rounds pass; the first piggybacks the ticket into Done.
    for _ in 0..2 {
        let other = rng.normal_vec(u.d);
        let ol = u.idx.probe(&other, u.nprobe);
        let batch = [BatchQuery { query: &other, lists: &ol, trace_id: 0 }];
        disp.search_batch(&batch, &u.idx.pq.centroids, u.nprobe).unwrap();
    }
    // Other slots tear down; slot 7's parked result is untouched.
    assert_eq!(disp.cancel_slot(0), 0);
    assert_eq!(disp.cancel_slot(1), 0);
    assert_eq!(disp.in_flight_for(7), 1);
    let got = disp.poll(t, &u.idx.pq.centroids).unwrap().unwrap();
    assert_topk_equiv(&got.topk, &want.topk, "parked result");
    assert_eq!(disp.in_flight(), 0);
}
