//! Integration tests over the live PJRT runtime + AOT artifacts.
//!
//! These are the L2<->L3 bridge checks: the rust-native substrates (PQ
//! scan, IVF, top-K) must agree numerically with the AOT-compiled Pallas
//! pipelines, and the end-to-end engines must run. Requires
//! `make artifacts` to have produced `artifacts/`.

use chameleon::chamlm::pool::WorkerPool;
use chameleon::chamlm::worker::GpuWorker;
use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::engine::RalmEngine;
use chameleon::coordinator::retriever::Retriever;
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::NodeClient;
use chameleon::net::server::NodeServer;
use chameleon::runtime::{HostTensor, Runtime};
use chameleon::util::rng::Rng;

fn artifacts_dir() -> String {
    std::env::var("CHAMELEON_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

/// A live PJRT runtime, or `None` in environments without the real
/// xla_extension / AOT artifacts (the vendored offline xla stub). Tests
/// that need execution skip themselves in that case — the native-engine
/// and modeled paths are covered by the unit tests and the other
/// integration files either way.
fn try_runtime() -> Option<Runtime> {
    match Runtime::new(&artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT integration test (run `make artifacts` with the real xla crate): {e}");
            None
        }
    }
}

// ---------------------------------------------------------------- ChamVS

/// The AOT Pallas scan pipeline must reproduce the native rust ADC + topk
/// results on the same shard data.
#[test]
fn pjrt_scan_matches_native_scan() {
    let Some(rt) = try_runtime() else { return };
    let mut rng = Rng::new(1);
    let (n, d, m, nlist) = (3000, 128, 16, 32);
    let ds = SyntheticDataset::generate_sized(&config::SIFT, n, 8, 5);
    let index = IvfPqIndex::build(&ds.data, n, d, m, nlist, 9);

    let shard_native = Shard::carve(&index, 0, 1);
    let shard_pjrt = Shard::carve(&index, 0, 1);
    let mut native = MemoryNode::new(shard_native, ScanEngine::Native, 10);
    // The artifact implements the approximate hierarchical top-K; compare
    // against the software model of the same module, not the fused exact
    // serving selector.
    native.select = chameleon::kselect::SelectMode::Hierarchical;
    let mut pjrt = MemoryNode::with_pjrt(shard_pjrt, &rt, 10, 3).unwrap();

    for qi in 0..4 {
        let q = ds.query(qi);
        let lists = index.probe(q, 8);
        let lut = chameleon::pq::scan::build_lut(&index.pq, q);
        let a = native.scan(&lut, q, &index.pq.centroids, &lists, 8).unwrap();
        let b = pjrt.scan(&lut, q, &index.pq.centroids, &lists, 8).unwrap();
        assert_eq!(a.topk.len(), b.topk.len());
        for (x, y) in a.topk.iter().zip(&b.topk) {
            assert!(
                (x.0 - y.0).abs() < 1e-2 * x.0.abs().max(1.0),
                "query {qi}: {} vs {}",
                x.0,
                y.0
            );
            assert_eq!(x.1, y.1, "query {qi}: id mismatch");
        }
    }
    let _ = rng.next_u64();
}

/// The IVF-scan artifact must match the rust-native probe.
#[test]
fn pjrt_ivf_scan_matches_native_probe() {
    let Some(rt) = try_runtime() else { return };
    let exe = rt.executor("ivf_scan_d128_b1", 0).unwrap();
    let nlist = exe.spec.static_usize("nlist").unwrap();
    let nprobe = exe.spec.static_usize("nprobe").unwrap();
    let mut rng = Rng::new(2);
    let cents = rng.normal_vec(nlist * 128);
    let q = rng.normal_vec(128);
    let outs = exe
        .call(&[
            HostTensor::f32(&[1, 128], q.clone()),
            HostTensor::f32(&[nlist, 128], cents.clone()),
        ])
        .unwrap();
    let got_ids = outs[1].as_i32().unwrap();

    // Native probe over the same centroids.
    let mut dists: Vec<(f32, usize)> = (0..nlist)
        .map(|l| {
            let c = &cents[l * 128..(l + 1) * 128];
            let dd: f32 = q.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
            (dd, l)
        })
        .collect();
    dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let want: Vec<i32> = dists[..nprobe].iter().map(|&(_, l)| l as i32).collect();
    let overlap = got_ids.iter().filter(|i| want.contains(i)).count();
    assert!(overlap >= nprobe - 1, "{overlap}/{nprobe}");
}

// ---------------------------------------------------------------- ChamLM

#[test]
fn decode_step_produces_distribution() {
    let Some(rt) = try_runtime() else { return };
    let mut w = GpuWorker::new(&rt, &config::DEC_TINY, 0, 7).unwrap();
    let out = w.step(5, (&[], &[])).unwrap();
    assert_eq!(out.probs.len(), config::DEC_TINY.vocab);
    assert!(GpuWorker::check_probs(&out.probs), "bad distribution");
    assert_eq!(out.query_vec.len(), config::DEC_TINY.dim);
    // Second step with cache evolves the distribution.
    let out2 = w.step(9, (&[], &[])).unwrap();
    assert!(GpuWorker::check_probs(&out2.probs));
    assert_ne!(out.probs, out2.probs);
}

#[test]
fn knn_payload_shifts_distribution() {
    let Some(rt) = try_runtime() else { return };
    let mut w = GpuWorker::new(&rt, &config::DEC_TINY, 0, 7).unwrap();
    let baseline = w.step(5, (&[], &[])).unwrap();
    w.reset();
    // All K neighbors vote token 123 at distance 0.
    let ids = vec![123u32; w.knn_k];
    let dd = vec![0.0f32; w.knn_k];
    let knn = w.step(5, (&ids, &dd)).unwrap();
    assert!(
        knn.probs[123] > baseline.probs[123] + 0.1,
        "{} vs {}",
        knn.probs[123],
        baseline.probs[123]
    );
}

#[test]
fn decode_deterministic_same_seed() {
    let Some(rt) = try_runtime() else { return };
    let mut a = GpuWorker::new(&rt, &config::DEC_TINY, 0, 11).unwrap();
    let mut b = GpuWorker::new(&rt, &config::DEC_TINY, 1, 11).unwrap();
    let oa = a.step(3, (&[], &[])).unwrap();
    let ob = b.step(3, (&[], &[])).unwrap();
    assert_eq!(oa.probs, ob.probs);
}

#[test]
fn encdec_worker_encodes_and_steps() {
    let Some(rt) = try_runtime() else { return };
    let mut w = GpuWorker::new(&rt, &config::ENCDEC_TINY, 0, 13).unwrap();
    let s = w.enc_tokens();
    assert!(s > 0);
    let chunks: Vec<u32> = (0..s as u32).map(|i| i % 100).collect();
    w.encode(&chunks).unwrap();
    let out = w.step(1, (&[], &[])).unwrap();
    assert!(GpuWorker::check_probs(&out.probs));
}

// ------------------------------------------------------------ end-to-end

fn build_engine(rt: &Runtime) -> RalmEngine {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 3000, 8, 3);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, 5);
    let nodes = vec![MemoryNode::new(
        Shard::carve(&index, 0, 1),
        ScanEngine::Native,
        config::DEC_TINY.k,
    )];
    let dispatcher = Dispatcher::new(nodes, config::DEC_TINY.k);
    let corpus = Corpus::generate(3000, config::DEC_TINY.vocab, config::CHUNK_LEN, 7);
    let retriever = Retriever::new(ds, index, dispatcher, corpus);
    let pool = WorkerPool::new(rt, &config::DEC_TINY, 1, 17).unwrap();
    RalmEngine::new(pool, retriever, &config::DEC_S)
}

#[test]
fn end_to_end_generation() {
    let Some(rt) = try_runtime() else { return };
    let mut engine = build_engine(&rt);
    let stats = engine.generate(1, 16, 23).unwrap();
    assert_eq!(stats.tokens.len(), 16);
    // interval=1: every step retrieves.
    assert_eq!(stats.retrieval_steps.len(), 16);
    assert!(stats.tokens.iter().all(|&t| (t as usize) < config::DEC_TINY.vocab));
    assert!(stats.modeled_total() > 0.0);
}

#[test]
fn generation_deterministic() {
    let Some(rt) = try_runtime() else { return };
    let mut engine = build_engine(&rt);
    let a = engine.generate(1, 8, 99).unwrap();
    let b = engine.generate(1, 8, 99).unwrap();
    assert_eq!(a.tokens, b.tokens);
}

#[test]
fn batched_decode_matches_single_worker() {
    // The vmapped b8 artifact must agree with 8 independent b1 workers
    // stepped with the same tokens/payloads (params share the same seed).
    let Some(rt) = try_runtime() else { return };
    let mut bw =
        chameleon::chamlm::batch_worker::BatchWorker::new(&rt, &config::DEC_TINY, 8, 7)
            .unwrap();
    let mut w = GpuWorker::new(&rt, &config::DEC_TINY, 0, 7).unwrap();
    let tokens: Vec<u32> = (0..8).map(|i| 10 + i).collect();
    let payloads: Vec<(Vec<u32>, Vec<f32>)> =
        (0..8).map(|_| (Vec::new(), Vec::new())).collect();
    let out = bw.step(&tokens, &payloads).unwrap();
    // Compare sequence 0 against the single worker on the same token.
    let single = w.step(tokens[0], (&[], &[])).unwrap();
    let b0 = out.probs_of(0);
    let mut max_diff = 0.0f32;
    for (a, b) in b0.iter().zip(&single.probs) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 1e-4, "batched vs single diff {max_diff}");
    assert!(GpuWorker::check_probs(b0));
    // All 8 rows are valid distributions.
    for s in 0..8 {
        assert!(GpuWorker::check_probs(out.probs_of(s)), "row {s}");
    }
}

// --------------------------------------------------------- disaggregated

#[test]
fn networked_nodes_match_local_dispatcher() {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let n = 2000;
    let seed = 31;
    let data = SyntheticDataset::generate_sized(ds, n, 8, seed);
    let index = IvfPqIndex::build(&data.data, n, data.d, ds.m, 32, seed ^ 1);
    let codebook = index.pq.centroids.clone();

    // Two networked nodes (built inside server threads).
    let mk_server = |node_id: usize| {
        let data = SyntheticDataset::generate_sized(ds, n, 8, seed);
        let index = IvfPqIndex::build(&data.data, n, data.d, ds.m, 32, seed ^ 1);
        let cb = index.pq.centroids.clone();
        NodeServer::spawn_with(
            move || {
                let mut node = MemoryNode::new(
                    Shard::carve(&index, node_id, 2),
                    ScanEngine::Native,
                    10,
                );
                node.kcfg = chameleon::kselect::HierarchicalConfig::exact(
                    10,
                    node.kcfg.num_lanes,
                );
                node
            },
            cb,
            ds.nprobe,
        )
        .unwrap()
    };
    let s0 = mk_server(0);
    let s1 = mk_server(1);
    let mut client = NodeClient::connect(&[s0.addr, s1.addr], 10).unwrap();

    // Local reference: monolithic exact search.
    for qi in 0..3 {
        let q = data.query(qi);
        let lists = index.probe(q, ds.nprobe);
        let got = client.search(q, &lists).unwrap().topk;
        let (_, want_d) = index.search(q, ds.nprobe, 10);
        assert_eq!(got.len(), 10);
        for (g, w) in got.iter().zip(&want_d) {
            assert!((g.0 - w).abs() < 1e-4, "query {qi}: {} vs {w}", g.0);
        }
    }

    // Batched round over the same connections: one BatchScanRequest per
    // node carries all queries; results must equal the single-query path.
    let queries: Vec<&[f32]> = (0..3).map(|qi| data.query(qi)).collect();
    let lists: Vec<Vec<u32>> =
        queries.iter().map(|q| index.probe(q, ds.nprobe)).collect();
    let batch: Vec<chameleon::chamvs::dispatcher::BatchQuery> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| chameleon::chamvs::dispatcher::BatchQuery {
            query: q,
            lists: l,
            trace_id: 0,
        })
        .collect();
    let rs = client.search_batch(&batch).unwrap();
    assert_eq!(rs.len(), 3);
    for (qi, r) in rs.iter().enumerate() {
        let single = client.search(queries[qi], &lists[qi]).unwrap();
        assert_eq!(r.topk, single.topk, "batched vs single, query {qi}");
        assert!(r.measured_wall_s > 0.0, "remote wall must be non-zero");
    }
    client.shutdown_nodes();
    let _ = codebook;
}

// -------------------------------------------------------------- failure

#[test]
fn worker_rejects_overflow_sequence() {
    let Some(rt) = try_runtime() else { return };
    let mut w = GpuWorker::new(&rt, &config::DEC_TINY, 0, 7).unwrap();
    // max_seq steps are fine; the next must error, not corrupt state.
    for i in 0..16 {
        w.step((i % 100) as u32, (&[], &[])).unwrap();
    }
    w.steps = config::DEC_TINY.max_seq as u64; // fast-forward
    assert!(w.step(1, (&[], &[])).is_err());
}

#[test]
fn executor_rejects_wrong_arg_count() {
    let Some(rt) = try_runtime() else { return };
    let exe = rt.executor("ivf_scan_d128_b1", 0).unwrap();
    let bad = exe.call(&[HostTensor::f32(&[1, 128], vec![0.0; 128])]);
    assert!(bad.is_err());
}

#[test]
fn manifest_missing_artifact_errors() {
    let Some(rt) = try_runtime() else { return };
    assert!(rt.executor("no_such_artifact", 0).is_err());
}
