//! Steady-state allocation pin for the zero-copy scan pipeline.
//!
//! A counting global allocator measures how many heap allocations one
//! batched dispatch round performs. After warmup (scratch tiles, selector
//! pools, LUT arena and round maps grown once), every identical round
//! must allocate exactly the same, bounded amount — the per-job result
//! vectors and round bookkeeping, never per-code or per-list copies. A
//! drifting count means a reuse buffer regressed into per-round
//! allocation.
//!
//! This file holds a single test on purpose: the counter is global, so
//! no sibling test may run concurrently in this binary.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use chameleon::chamvs::dispatcher::{BatchQuery, Dispatcher};
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::util::rng::Rng;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_rounds_allocate_a_constant_bounded_amount() {
    let mut rng = Rng::new(41);
    let (n, d, m, nlist) = (3000, 32, 8, 32);
    let data = rng.normal_vec(n * d);
    let idx = IvfPqIndex::build(&data, n, d, m, nlist, 3);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&idx, i, 2), ScanEngine::Native, 10))
        .collect();
    let mut disp = Dispatcher::new(nodes, 10);
    // Inline dispatch: thread spawns would charge runtime allocations to
    // the round. The scan/select/arena reuse under test is identical at
    // any width.
    disp.n_threads = 1;

    let queries: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(d)).collect();
    let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 8)).collect();
    let batch: Vec<BatchQuery> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
        .collect();

    // Warmup: grows the LUT arena, distance tiles, selector pool and
    // round maps to their steady-state capacity.
    for _ in 0..3 {
        disp.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
    }

    let mut per_round = Vec::new();
    for _ in 0..5 {
        let before = ALLOCS.load(Ordering::Relaxed);
        let r = disp.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
        let after = ALLOCS.load(Ordering::Relaxed);
        assert_eq!(r.len(), batch.len());
        drop(r);
        per_round.push(after - before);
    }
    let min = *per_round.iter().min().unwrap();
    let max = *per_round.iter().max().unwrap();
    assert_eq!(
        min, max,
        "steady-state rounds must allocate a constant amount: {per_round:?}"
    );
    // 4 jobs x 2 nodes: per-job top-K vectors + round bookkeeping only.
    // Gather copies / per-query LUT or scratch allocation would blow far
    // past this.
    assert!(max <= 96, "round allocated {max} times: {per_round:?}");
}
