//! Zero-allocation pin for trace recording.
//!
//! The tracer sits on the serving hot path (one `record` per stage per
//! query), so it must never touch the heap after construction: the ring
//! is preallocated and recording is a ticket fetch-add plus volatile slot
//! writes. A counting global allocator proves it — thousands of records,
//! including wrap-around past the ring capacity, charge exactly zero
//! allocations.
//!
//! This file holds a single test on purpose: the counter is global, so
//! no sibling test may run concurrently in this binary (same harness as
//! `scan_alloc.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use chameleon::trace::{SpanKind, Tracer};

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn recording_spans_never_allocates() {
    // Construction allocates the ring once; everything after it must not.
    let tracer = Tracer::new(1024);
    let off = Tracer::off();

    // Warmup (exercise every kind once; clones share the ring).
    let clone = tracer.clone();
    for (i, kind) in [
        SpanKind::QueueWait,
        SpanKind::LutBuild,
        SpanKind::NodeScan,
        SpanKind::Merge,
        SpanKind::HedgeFired,
        SpanKind::HedgeWon,
        SpanKind::CacheProbe,
        SpanKind::SpecVerify,
        SpanKind::ReplyWrite,
        SpanKind::Total,
    ]
    .into_iter()
    .enumerate()
    {
        clone.record(1, kind, i as u32, 1e-6);
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    // 8x the ring capacity: wrap-around reclaims slots in place.
    for i in 0..8 * 1024u64 {
        tracer.record(i + 1, SpanKind::NodeScan, (i % 4) as u32, 2e-6);
        clone.record(i + 1, SpanKind::Merge, 0, 1e-6);
        off.record(i + 1, SpanKind::Total, 0, 3e-6); // no-op path
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "trace recording must not allocate ({} allocations over {} records)",
        after - before,
        3 * 8 * 1024
    );

    // The ring really kept the most recent events: a snapshot drains
    // capacity-many, all from the tail of the stream.
    let events = tracer.snapshot();
    assert_eq!(events.len(), 1024);
    assert!(events.iter().all(|e| e.trace_id > 7 * 1024));
}
