//! Failure-injection tests: the coordinator must degrade gracefully when
//! memory nodes die, frames are corrupt, or artifacts are missing.

use std::io::Write;
use std::net::TcpStream;

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::chamvs::ScanBackend;
use chameleon::cluster::{ClusterConfig, ClusterEngine, ClusterNode, SelectPolicy};
use chameleon::config;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::{NodeClient, RemoteNode};
use chameleon::net::protocol::{Frame, Kind, ScanRequest};
use chameleon::net::server::NodeServer;

fn spawn_node(seed: u64) -> (NodeServer, IvfPqIndex, SyntheticDataset) {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 1500, 8, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 16, seed ^ 1);
    let cb = index.pq.centroids.clone();
    let data2 = SyntheticDataset::generate_sized(ds, 1500, 8, seed);
    let index2 = IvfPqIndex::build(&data2.data, data2.n, data2.d, ds.m, 16, seed ^ 1);
    let server = NodeServer::spawn_with(
        move || MemoryNode::new(Shard::carve(&index2, 0, 1), ScanEngine::Native, 10),
        cb,
        8,
    )
    .unwrap();
    (server, index, data)
}

#[test]
fn client_errors_when_node_dies_mid_query() {
    let (mut server, index, data) = spawn_node(1);
    let mut client = NodeClient::connect(&[server.addr], 10).unwrap();
    // Healthy query first.
    let q = data.query(0);
    let lists = index.probe(q, 8);
    let r = client.search(q, &lists).unwrap();
    assert_eq!(r.topk.len(), 10);
    assert!(r.measured_wall_s > 0.0, "node-side wall must be carried over the wire");
    // Kill the node, then query again: must be an Err, not a hang/panic.
    server.shutdown();
    let res = client.search(q, &lists);
    assert!(res.is_err(), "expected error after node death");
}

#[test]
fn server_survives_garbage_bytes() {
    let (server, index, data) = spawn_node(2);
    // Throw garbage at the node on one connection...
    {
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.write_all(b"this is not a chameleon frame at all............").unwrap();
    } // connection dropped
    // ... a fresh, well-formed connection must still be served.
    let mut client = NodeClient::connect(&[server.addr], 10).unwrap();
    let q = data.query(1);
    let lists = index.probe(q, 8);
    let r = client.search(q, &lists).unwrap();
    assert_eq!(r.topk.len(), 10);
    client.shutdown_nodes();
}

#[test]
fn server_rejects_oversized_frame_gracefully() {
    let (server, _index, _data) = spawn_node(3);
    let mut s = TcpStream::connect(server.addr).unwrap();
    // Valid magic/kind but an absurd length; server must drop the
    // connection without dying.
    use byteorder::{LittleEndian, WriteBytesExt};
    s.write_u32::<LittleEndian>(chameleon::net::protocol::MAGIC).unwrap();
    s.write_u32::<LittleEndian>(1).unwrap();
    s.write_u64::<LittleEndian>(u64::MAX / 2).unwrap();
    drop(s);
    // Server still answers.
    let mut client = NodeClient::connect(&[server.addr], 10).unwrap();
    // Empty probe list: node returns empty topk, not an error.
    let req_q = vec![0.0f32; 128];
    let r = client.search(&req_q, &[]).unwrap();
    assert!(r.topk.is_empty());
    client.shutdown_nodes();
}

#[test]
fn scan_request_with_out_of_range_list_is_filtered() {
    let (server, _index, data) = spawn_node(4);
    let s = TcpStream::connect(server.addr).unwrap();
    let mut writer = s.try_clone().unwrap();
    let mut reader = std::io::BufReader::new(s);
    // The node greets each connection with its identity + PQ geometry.
    let hello = Frame::read_from(&mut reader).unwrap();
    let hello = chameleon::net::protocol::Hello::decode(&hello).unwrap();
    assert!(hello.m > 0);
    assert!(hello.nlist > 0);
    let req = ScanRequest {
        query_id: 1,
        query: data.query(0).to_vec(),
        lists: vec![10_000], // out of range: node must filter, not die
        k: 10,
    };
    req.encode().write_to(&mut writer).unwrap();
    let resp = Frame::read_from(&mut reader).unwrap();
    assert_eq!(resp.kind, Kind::ScanResponse);
    let resp = chameleon::net::protocol::ScanResponse::decode(&resp).unwrap();
    assert!(resp.ids.is_empty(), "no valid lists => no results");
}

#[test]
fn runtime_missing_artifacts_dir_errors() {
    let r = chameleon::runtime::Runtime::new("/nonexistent/artifacts");
    assert!(r.is_err());
}

/// Two networked replicas of the same (whole-index) shard behind the
/// cluster engine: killing the primary mid-workload must not fail the
/// query — dispatch completes on the surviving replica with bit-identical
/// top-k. (This upgrades `client_errors_when_node_dies_mid_query` from
/// "the error is detected" to "the error is survived".)
#[test]
fn dispatch_fails_over_to_replica_with_identical_topk() {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let seed = 21u64;
    let data = SyntheticDataset::generate_sized(ds, 1500, 8, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 16, seed ^ 1);
    // Each replica process rebuilds the identical 1-shard carve.
    let spawn_replica = || {
        let data = SyntheticDataset::generate_sized(ds, 1500, 8, seed);
        let idx = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 16, seed ^ 1);
        let cb = idx.pq.centroids.clone();
        NodeServer::spawn_with(
            move || MemoryNode::new(Shard::carve(&idx, 0, 1), ScanEngine::Native, 10),
            cb,
            8,
        )
        .unwrap()
    };
    let mut primary = spawn_replica();
    let secondary = spawn_replica();

    let nodes = vec![
        ClusterNode {
            id: 0,
            shard: 0,
            backend: Box::new(RemoteNode::connect(primary.addr, 10).unwrap())
                as Box<dyn ScanBackend>,
        },
        ClusterNode {
            id: 1,
            shard: 0,
            backend: Box::new(RemoteNode::connect(secondary.addr, 10).unwrap())
                as Box<dyn ScanBackend>,
        },
    ];
    // Static selection pins node 0 as the primary so the kill is
    // guaranteed to hit the serving replica.
    let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
    let engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, 10);

    let q = data.query(0);
    let lists = index.probe(q, 8);
    let healthy = disp.search(q, &index.pq.centroids, &lists, 8).unwrap();
    assert_eq!(healthy.topk.len(), 10);

    // Kill the primary: the dead socket errors fast, the engine retries
    // on the replica, and the caller sees zero failures.
    primary.shutdown();
    let after = disp.search(q, &index.pq.centroids, &lists, 8).unwrap();
    assert_eq!(
        after.topk, healthy.topk,
        "failover result must be bit-identical to the healthy cluster"
    );
    let stats = disp.cluster().unwrap().stats();
    assert!(stats.failovers >= 1, "replica must have served the round: {stats:?}");
    drop(secondary);
}
