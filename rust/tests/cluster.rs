//! Elastic retrieval tier integration tests: replicated dispatch
//! bit-identity, the ISSUE-5 acceptance pin (killing any single node at
//! replication 2 yields zero failed queries and identical top-k), hedged
//! dispatch, and live membership transitions through the coordinator
//! server's epoch-swap path.

use std::time::Duration;

use chameleon::chamvs::dispatcher::{BatchQuery, Dispatcher};
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::chamvs::ScanBackend;
use chameleon::cluster::{
    ClusterConfig, ClusterEngine, ClusterMap, ClusterNode, DegradedPolicy,
    FailingBackend, HedgeConfig, OutageBackend, RoundOptions, SelectPolicy,
    StragglerBackend,
};
use chameleon::config;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::net::client::RemoteNode;
use chameleon::net::protocol::{ClusterOp, ClusterUpdate};
use chameleon::net::server::NodeServer;
use chameleon::util::rng::Rng;

fn toy_index(seed: u64) -> (IvfPqIndex, usize) {
    let mut rng = Rng::new(seed);
    let (n, d, m, nlist) = (3000, 32, 8, 32);
    let data = rng.normal_vec(n * d);
    (IvfPqIndex::build(&data, n, d, m, nlist, seed ^ 1), d)
}

fn mk_node(index: &IvfPqIndex, shard: usize, n_shards: usize, k: usize) -> Box<dyn ScanBackend> {
    Box::new(MemoryNode::new(Shard::carve(index, shard, n_shards), ScanEngine::Native, k))
}

/// Flat reference dispatcher: one node per shard over the same carve.
fn flat_reference(index: &IvfPqIndex, n_shards: usize, k: usize) -> Dispatcher {
    let nodes: Vec<MemoryNode> = (0..n_shards)
        .map(|s| MemoryNode::new(Shard::carve(index, s, n_shards), ScanEngine::Native, k))
        .collect();
    Dispatcher::new(nodes, k)
}

#[test]
fn clustered_dispatch_is_bit_identical_to_flat() {
    let (idx, d) = toy_index(1);
    let engine = ClusterEngine::local(&idx, 4, 2, 10, ClusterConfig::default()).unwrap();
    let mut clustered = Dispatcher::clustered(engine, 10);
    let mut flat = flat_reference(&idx, 2, 10);
    let mut rng = Rng::new(5);
    // Single-query rounds.
    for _ in 0..4 {
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        let want = flat.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        let got = clustered.search(&q, &idx.pq.centroids, &lists, 8).unwrap();
        assert_eq!(got.topk, want.topk);
        assert_eq!(got.n_scanned, want.n_scanned);
        assert!(got.measured_wall_s > 0.0);
    }
    // Batched rounds through the same engine.
    let queries: Vec<Vec<f32>> = (0..5).map(|_| rng.normal_vec(d)).collect();
    let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 8)).collect();
    let batch: Vec<BatchQuery> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| BatchQuery { query: q, lists: l, trace_id: 0 })
        .collect();
    let want = flat.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
    let got = clustered.search_batch(&batch, &idx.pq.centroids, 8).unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.topk, w.topk);
    }
}

/// ISSUE 5 acceptance: with replication factor 2, killing ANY single
/// memory node mid-workload yields zero failed queries and top-k results
/// bit-identical to the healthy cluster.
#[test]
fn killing_any_single_node_is_invisible_at_replication_2() {
    let (idx, d) = toy_index(2);
    let (n_nodes, replication, k) = (4usize, 2usize, 10usize);
    let n_shards = n_nodes / replication;
    let mut flat = flat_reference(&idx, n_shards, k);
    let mut rng = Rng::new(9);
    let queries: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(d)).collect();
    let lists: Vec<Vec<u32>> = queries.iter().map(|q| idx.probe(q, 8)).collect();
    let want: Vec<Vec<(f32, u64)>> = queries
        .iter()
        .zip(&lists)
        .map(|(q, l)| flat.search(q, &idx.pq.centroids, l, 8).unwrap().topk)
        .collect();

    let kill_at = 3usize; // scans observed by the victim before dying
    // Static selection makes each shard's primary deterministic (shard 0:
    // node 0 of [0, 2]; shard 1: node 3 of the rotated [3, 1]), so a
    // primary victim is GUARANTEED to serve, die mid-run, and fail over —
    // health-aware selection is sticky and could starve the victim of
    // scans, turning the death into a coin flip.
    let static_primaries = [0u32, 3];
    for victim in 0..n_nodes as u32 {
        let plan = ClusterMap::carve_plan(n_nodes, replication).unwrap();
        let nodes: Vec<ClusterNode> = plan
            .into_iter()
            .map(|(id, shard)| {
                let backend = mk_node(&idx, shard, n_shards, k);
                let backend = if id == victim {
                    Box::new(FailingBackend::new(backend, kill_at)) as Box<dyn ScanBackend>
                } else {
                    backend
                };
                ClusterNode { id, shard, backend }
            })
            .collect();
        let cfg = ClusterConfig { select: SelectPolicy::Static, ..Default::default() };
        let engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
        let mut disp = Dispatcher::clustered(engine, k);
        for ((q, l), w) in queries.iter().zip(&lists).zip(&want) {
            let got = disp
                .search(q, &idx.pq.centroids, l, 8)
                .unwrap_or_else(|e| panic!("victim {victim}: query failed: {e:#}"));
            assert_eq!(&got.topk, w, "victim {victim}: top-k diverged");
        }
        // A serving (primary) victim must actually have died and been
        // rescued; a standby victim's death is trivially invisible.
        if static_primaries.contains(&victim) {
            let stats = disp.cluster().unwrap().stats();
            assert!(
                stats.failovers >= 1,
                "victim {victim} was a primary: its replica must have served \
                 ({stats:?})"
            );
        }
    }
}

/// ISSUE 9 acceptance: with BOTH of shard 0's replicas dead mid-workload
/// under `DegradedPolicy::ServePartial`, every query still answers —
/// tagged `coverage < 1.0`, zero hard failures — and once the replicas
/// heal and pass half-open probation, top-k returns to bit-identical
/// with the no-fault flat reference.
#[test]
fn dark_shard_serves_partials_then_rejoins_bit_identical() {
    let (idx, d) = toy_index(7);
    let (n_nodes, replication, k) = (4usize, 2usize, 10usize);
    let n_shards = n_nodes / replication;
    let mut flat = flat_reference(&idx, n_shards, k);
    let mut rng = Rng::new(17);
    let q = rng.normal_vec(d);
    let lists = idx.probe(&q, 8);
    let want = flat.search(&q, &idx.pq.centroids, &lists, 8).unwrap().topk;

    // Outage windows are per-node *call* counts: the static primary
    // (node 0) serves two healthy scans then dies; its replica (node 2)
    // is dead from its very first scan — so from query 2 on, shard 0 has
    // no healthy replica until both outages end and probation readmits
    // them. Shard 1 stays healthy throughout.
    let plan = ClusterMap::carve_plan(n_nodes, replication).unwrap();
    let nodes: Vec<ClusterNode> = plan
        .into_iter()
        .map(|(id, shard)| {
            let backend = mk_node(&idx, shard, n_shards, k);
            let backend = match id {
                0 => Box::new(OutageBackend::new(backend, 2, 4)) as Box<dyn ScanBackend>,
                2 => Box::new(OutageBackend::new(backend, 0, 2)) as Box<dyn ScanBackend>,
                _ => backend,
            };
            ClusterNode { id, shard, backend }
        })
        .collect();
    let cfg = ClusterConfig {
        select: SelectPolicy::Static,
        breaker_threshold: 1,
        ..Default::default()
    };
    let mut engine = ClusterEngine::new(nodes, n_shards, cfg).unwrap();
    engine.health_mut().breaker_backoff = Duration::from_millis(5);
    let mut disp = Dispatcher::clustered(engine, k);
    let opts = RoundOptions {
        degraded: DegradedPolicy::ServePartial { min_coverage: 0.0 },
        ..Default::default()
    };

    // Healthy phase: shard 0's primary serves its two good scans.
    for _ in 0..2 {
        let got = disp
            .search_opts(&q, &idx.pq.centroids, &lists, 8, 0, &opts)
            .expect("healthy phase must not fail");
        assert!(!got.is_partial(), "healthy phase must be complete");
        assert_eq!(got.topk, want);
    }

    // Dark phase: keep querying until probation readmits a healed
    // replica and a complete round comes back. Every answer in between
    // must be a coverage-tagged partial — never a hard failure.
    let mut partials = 0usize;
    let mut recovered = false;
    for _ in 0..200 {
        let got = disp
            .search_opts(&q, &idx.pq.centroids, &lists, 8, 0, &opts)
            .expect("ServePartial must absorb the dark shard");
        if got.is_partial() {
            assert!(
                (got.coverage() - 0.5).abs() < 1e-9,
                "one of two shards answered: coverage must be 1/2"
            );
            partials += 1;
            std::thread::sleep(Duration::from_millis(10));
        } else {
            assert_eq!(got.topk, want, "post-rejoin top-k must be bit-identical");
            recovered = true;
            break;
        }
    }
    assert!(partials >= 1, "the dark window must have produced partials");
    assert!(recovered, "probation never readmitted the healed replicas");

    // Steady state after rejoin: complete and bit-identical again, the
    // probe(s) that readmitted the nodes matched the winner exactly.
    let got = disp.search_opts(&q, &idx.pq.centroids, &lists, 8, 0, &opts).unwrap();
    assert!(!got.is_partial());
    assert_eq!(got.topk, want);
    let stats = disp.cluster().unwrap().stats();
    assert!(stats.probes >= 1, "rejoin must go through half-open probation: {stats:?}");
    assert_eq!(stats.probe_mismatches, 0, "probes over identical carves match");
    assert!(stats.partial_rounds as usize >= partials);
}

#[test]
fn hedge_fires_and_wins_against_a_blocked_primary() {
    let (idx, d) = toy_index(3);
    let k = 10;
    // Shard 0's primary straggles on every second call; the replica is
    // healthy. Static selection keeps the straggler primary, so only
    // hedging can rescue the slow rounds. The fast rounds warm the
    // recent-latency window with a sub-millisecond baseline, making a
    // low quantile a tight deadline for the 40 ms straggles.
    let straggler = StragglerBackend::new(mk_node(&idx, 0, 1, k), Duration::from_millis(40), 2);
    let nodes = vec![
        ClusterNode { id: 0, shard: 0, backend: Box::new(straggler) },
        ClusterNode { id: 1, shard: 0, backend: mk_node(&idx, 0, 1, k) },
    ];
    let cfg = ClusterConfig {
        select: SelectPolicy::Static,
        hedge: Some(HedgeConfig { quantile: 0.25, floor: Duration::from_micros(100) }),
        ..Default::default()
    };
    let mut engine = ClusterEngine::new(nodes, 1, cfg).unwrap();
    let mut rng = Rng::new(13);
    let run = |engine: &mut ClusterEngine, rng: &mut Rng| {
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 6);
        let lut = chameleon::pq::scan::build_lut(&idx.pq, &q);
        let jobs = [chameleon::chamvs::ScanJob {
            query: &q,
            lists: &lists,
            lut: &lut,
            nprobe: 6,
        }];
        engine.run_round(&jobs, &idx.pq.centroids).unwrap();
    };
    // Warm the latency window (hedging stays off until it has a
    // baseline of at least 8 samples).
    for _ in 0..12 {
        run(&mut engine, &mut rng);
    }
    let before = engine.stats();
    for _ in 0..8 {
        run(&mut engine, &mut rng);
    }
    let after = engine.stats();
    assert!(
        after.hedges > before.hedges,
        "hedges must fire once the window is warm: {after:?}"
    );
    assert!(
        after.hedge_wins > before.hedge_wins,
        "the healthy replica must win hedged rounds: {after:?}"
    );
}

/// Live membership transitions through the coordinator server: join a
/// replica, drain + remove the original — between batches, with requests
/// flowing before and after, and the epoch visible in every ack.
#[test]
fn coordinator_applies_cluster_updates_between_batches() {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let seed = 31u64;
    let n = 2000usize;
    // Three node processes: two replicas of shard 0, one of shard 1
    // (shard identity comes from the carve each server holds).
    let spawn = |shard: usize| {
        let data = SyntheticDataset::generate_sized(ds, n, 8, seed);
        let idx = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
        let cb = idx.pq.centroids.clone();
        NodeServer::spawn_with(
            move || MemoryNode::new(Shard::carve(&idx, shard, 2), ScanEngine::Native, 10),
            cb,
            8,
        )
        .unwrap()
    };
    let node_a = spawn(0); // initial shard-0 member
    let node_b = spawn(1); // shard-1 member
    let node_c = spawn(0); // joins later as shard-0 replica
    let c_addr = node_c.addr;

    let (a_addr, b_addr) = (node_a.addr, node_b.addr);
    let builder = move || {
        let data = SyntheticDataset::generate_sized(ds, n, 8, seed);
        let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
        let nodes = vec![
            ClusterNode {
                id: 0,
                shard: 0,
                backend: Box::new(RemoteNode::connect(a_addr, 10).unwrap())
                    as Box<dyn ScanBackend>,
            },
            ClusterNode {
                id: 1,
                shard: 1,
                backend: Box::new(RemoteNode::connect(b_addr, 10).unwrap())
                    as Box<dyn ScanBackend>,
            },
        ];
        let engine = ClusterEngine::new(nodes, 2, ClusterConfig::default()).unwrap();
        let corpus = Corpus::generate(n, 2048, config::CHUNK_LEN, seed ^ 2);
        Retriever::new(ds, index, Dispatcher::clustered(engine, 10), corpus)
    };
    let mut server = CoordinatorServer::spawn_with(builder).unwrap();
    let mut client = CoordinatorClient::connect(server.addr, 0).unwrap();
    let data = SyntheticDataset::generate_sized(ds, n, 8, seed);

    let before = client.retrieve(data.query(0), &[], 10, false).unwrap();
    assert_eq!(before.tokens.len(), 10);

    // Join node C as a second shard-0 replica.
    let ack = client
        .cluster_update(&ClusterUpdate {
            op: ClusterOp::Join,
            node_id: 2,
            shard: 0,
            addr: c_addr.to_string(),
        })
        .unwrap();
    assert!(ack.ok, "{}", ack.message);
    let epoch_after_join = ack.epoch;

    // Drain then remove the original shard-0 member; epochs advance.
    let ack = client
        .cluster_update(&ClusterUpdate {
            op: ClusterOp::Drain,
            node_id: 0,
            shard: 0,
            addr: String::new(),
        })
        .unwrap();
    assert!(ack.ok, "{}", ack.message);
    assert_eq!(ack.epoch, epoch_after_join + 1);
    let ack = client
        .cluster_update(&ClusterUpdate {
            op: ClusterOp::Remove,
            node_id: 0,
            shard: 0,
            addr: String::new(),
        })
        .unwrap();
    assert!(ack.ok, "{}", ack.message);
    assert_eq!(ack.epoch, epoch_after_join + 2);

    // Traffic keeps flowing under the new epoch, with identical payloads
    // (node C holds the same shard-0 carve node A did).
    let after = client.retrieve(data.query(0), &[], 10, false).unwrap();
    assert_eq!(after.tokens, before.tokens);
    assert_eq!(after.dists, before.dists);

    // Draining the last replica of a shard must be refused.
    let ack = client
        .cluster_update(&ClusterUpdate {
            op: ClusterOp::Drain,
            node_id: 1,
            shard: 1,
            addr: String::new(),
        })
        .unwrap();
    assert!(!ack.ok, "uncovering shard 1 must be refused");

    client.shutdown_coordinator();
    server.shutdown();
    drop(node_b);
    drop(node_c);
    // node_a was drained and removed: its connection closed, so the
    // server retires on its own; dropping it here just joins the thread.
    drop(node_a);
}

/// ISSUE-8 pin: with `pin_workers` on, engine workers pin to the
/// NUMA-interleaved plan and surface a stable observed CPU per node in
/// `ClusterStats::pinned`. Skips (with a printed reason) where affinity
/// is unsupported or the sandbox denies `sched_setaffinity`.
#[test]
fn pinned_workers_report_stable_cpus_in_cluster_stats() {
    use chameleon::cluster::NodeId;
    use chameleon::util::affinity;
    use std::collections::BTreeMap;

    if !affinity::supported() {
        eprintln!("affinity unsupported on this platform; skipping pin test");
        return;
    }
    let allowed = affinity::allowed_cpus();
    // Re-applying the current mask probes whether the sandbox allows
    // sched_setaffinity at all, without changing anything.
    if allowed.is_empty() || !affinity::pin_to_cpus(&allowed) {
        eprintln!("sched_setaffinity denied here; skipping pin test");
        return;
    }

    let (idx, d) = toy_index(21);
    let cfg = ClusterConfig { pin_workers: true, ..Default::default() };
    let engine = ClusterEngine::local(&idx, 4, 2, 10, cfg).unwrap();
    let mut disp = Dispatcher::clustered(engine, 10);
    let mut rng = Rng::new(33);

    let mut prev: BTreeMap<NodeId, usize> = BTreeMap::new();
    for round in 0..6 {
        let q = rng.normal_vec(d);
        let lists = idx.probe(&q, 8);
        disp.search(&q, &idx.pq.centroids, &lists, 8).unwrap();

        let stats = disp.cluster().unwrap().stats();
        for &(node, cpu) in &stats.pinned {
            assert!(
                allowed.contains(&cpu),
                "round {round}: node {node} reports cpu {cpu} outside the \
                 allowed set {allowed:?}"
            );
            // A worker pins once at spawn: its observed CPU never moves.
            if let Some(&seen) = prev.get(&node) {
                assert_eq!(
                    seen, cpu,
                    "round {round}: node {node} moved from cpu {seen} to {cpu}"
                );
            }
            prev.insert(node, cpu);
        }
    }
    assert!(
        !prev.is_empty(),
        "pinning enabled and sched_setaffinity works, yet no worker ever \
         reported a pinned CPU"
    );
}
