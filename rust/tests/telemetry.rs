//! Live telemetry plane tests: windowed-histogram bucket math against a
//! scalar reference, burn-rate fixtures, tear-free stats snapshots, the
//! tail sampler's retention rules, and the two live read paths — stats
//! protocol frames (conservation, SLO burn, flagged traces, the admin
//! gate) and the hand-rolled Prometheus scrape listener.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::admission::{QosConfig, BATCH_TENANT_BASE};
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{
    CoordinatorClient, CoordinatorServer, ServeMode, ServerStats,
};
use chameleon::coordinator::SloObjective;
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::telemetry::{
    bucket_index, bucket_upper_us, burn_rate, HistogramConfig, MetricsServer, Outcome,
    Registry, TailRecord, TailSampler, Telemetry, TelemetryConfig, Verdict,
    WindowedHistogram,
};
use chameleon::trace::Tracer;
use chameleon::util::json::Json;

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 2000, 32, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let corpus = Corpus::generate(2000, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, 10), corpus)
}

fn queries(seed: u64) -> SyntheticDataset {
    SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        32,
        seed,
    )
}

fn num(j: &Json, k: &str) -> i64 {
    j.get(k).and_then(|v| v.as_f64()).unwrap_or(-1.0) as i64
}

/// Deterministic window rotation via `record_at`: values land in their
/// log2 buckets, the fast window sees only the newest slot, and values
/// older than the retained horizon expire from the window view while the
/// lifetime totals keep them.
#[test]
fn windowed_histogram_rotation_and_expiry() {
    let h = WindowedHistogram::new(HistogramConfig {
        window: Duration::from_secs(1),
        windows: 3,
    });
    h.record_at(100, Duration::from_millis(500)); // window 0
    h.record_at(200, Duration::from_millis(1500)); // window 1
    h.record_at(400, Duration::from_millis(2500)); // window 2

    let t2 = Duration::from_millis(2500);
    let fast = h.aggregate_at(1, t2);
    assert_eq!(fast.count, 1, "fast window is the newest slot only");
    assert_eq!(fast.sum_us, 400);
    let all = h.aggregate_at(3, t2);
    assert_eq!(all.count, 3);
    assert_eq!(all.sum_us, 700);

    // Window 3 recycles slot 0 — the value 100 falls off the horizon.
    h.record_at(800, Duration::from_millis(3500));
    let t3 = Duration::from_millis(3500);
    let horizon = h.aggregate_at(3, t3);
    assert_eq!(horizon.count, 3, "expired slot still counted");
    assert_eq!(horizon.sum_us, 200 + 400 + 800);
    // count_above at the 255 boundary (2^8 - 1) is exact: 400 and 800.
    assert_eq!(horizon.count_above(255), 2);
    assert_eq!(horizon.quantile_us(1.0), bucket_upper_us(bucket_index(800)));

    // Totals never drop a sample.
    let tot = h.totals();
    assert_eq!(tot.count, 4);
    assert_eq!(tot.sum_us, 1500);
}

/// Histogram quantiles against a sorted scalar reference: the reported
/// quantile must be the upper bound of the bucket the true rank value
/// falls in.
#[test]
fn windowed_histogram_quantiles_match_scalar_reference() {
    let h = WindowedHistogram::new(HistogramConfig::default());
    let mut vals: Vec<u64> = Vec::new();
    let mut x: u64 = 0x3c6e_f372_fe94_f82b;
    for _ in 0..500 {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let v = (x >> 33) % 100_000;
        vals.push(v);
        h.record(v);
    }
    vals.sort_unstable();
    let tot = h.totals();
    assert_eq!(tot.count, 500);
    assert_eq!(tot.sum_us, vals.iter().sum::<u64>());
    for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
        let rank = ((q * 500.0).ceil() as usize).clamp(1, 500);
        let truth = vals[rank - 1];
        assert_eq!(
            tot.quantile_us(q),
            bucket_upper_us(bucket_index(truth)),
            "q={q} truth={truth}"
        );
    }
    // Breach counting vs the reference at an exact bucket boundary.
    let threshold = (1u64 << 12) - 1;
    let truth_above = vals.iter().filter(|&&v| v > threshold).count() as u64;
    assert_eq!(tot.count_above(threshold), truth_above);
}

/// Hand-computed burn-rate fixtures, including the degenerate corners.
#[test]
fn burn_rate_fixtures() {
    assert_eq!(burn_rate(2, 100, 0.01), 2.0);
    assert_eq!(burn_rate(5, 100, 0.05), 1.0);
    assert_eq!(burn_rate(0, 0, 0.01), 0.0, "no traffic burns nothing");
    assert_eq!(burn_rate(0, 100, 0.0), 0.0, "no bad events burns nothing");
    assert!(
        burn_rate(1, 100, 0.0).is_infinite(),
        "zero budget + a bad event burns infinitely fast"
    );
}

/// A breach shows up in the fast burn window immediately (the fast window
/// is the current slot, so no rotation has to pass first), and completes
/// leave availability burn at zero.
#[test]
fn burn_reacts_within_one_window() {
    let telemetry = Telemetry::new(TelemetryConfig {
        slo_interactive: Some(SloObjective {
            latency_us: 1000,
            target: 0.9,
            availability: 0.999,
        }),
        ..TelemetryConfig::default()
    });
    for i in 0..5 {
        telemetry.observe(0, 10_000, Outcome::Complete, 100 + i);
    }
    let burns = telemetry.burn_rates();
    assert_eq!(burns.len(), 1);
    let b = &burns[0];
    assert_eq!(b.tenant, 0);
    // Every request breached the 1 ms objective: (5/5) / (1 - 0.9) = 10.
    assert!((b.latency.fast - 10.0).abs() < 1e-9, "fast burn {}", b.latency.fast);
    assert!((b.latency.slow - 10.0).abs() < 1e-9);
    assert_eq!(b.availability.fast, 0.0, "all requests completed fully");
    assert_eq!(b.window_count, 5);
    assert!(b.p99_us >= 10_000);
    // Every breach was flagged by the tail sampler, trace ids intact.
    let tail = telemetry.sampler().snapshot();
    assert_eq!(tail.flagged.len(), 5);
    assert!(tail.flagged.iter().all(|r| r.verdict == Verdict::SloBreach));
    assert!(tail.flagged.iter().any(|r| r.trace_id == 104));
}

/// `ServerStats::snapshot` under a write storm: monotone across reads,
/// never crashes, and exact once writers quiesce. The writers drive the
/// same registry handles the server's hot path holds.
#[test]
fn server_stats_snapshot_tear_free() {
    let reg = Registry::default();
    let stats = ServerStats::new(&reg);
    let received = reg.counter("coordinator.requests.received");
    let replies = reg.counter("coordinator.replies");
    const WRITERS: usize = 4;
    const PER: u64 = 20_000;
    std::thread::scope(|s| {
        for _ in 0..WRITERS {
            let received = received.clone();
            let replies = replies.clone();
            s.spawn(move || {
                for _ in 0..PER {
                    received.inc();
                    replies.inc();
                }
            });
        }
        let mut last = 0u64;
        for _ in 0..200 {
            let snap = stats.snapshot();
            assert!(snap.received >= last, "received went backwards");
            last = snap.received;
        }
    });
    let snap = stats.snapshot();
    assert_eq!(snap.received, WRITERS as u64 * PER);
    assert_eq!(snap.replies, WRITERS as u64 * PER);
    assert_eq!(stats.received(), snap.received, "getters agree with snapshot");
}

/// Reservoir stays bounded, flagged traces are retained newest-wins, and
/// a flagged exemplar is never displaced by an unflagged one.
#[test]
fn tail_sampler_retention_rules() {
    let sampler = TailSampler::new(8, 4, 42);
    for i in 0..100u64 {
        sampler.offer(TailRecord {
            trace_id: i,
            tenant: 0,
            total_us: 500,
            verdict: Verdict::Ok,
        });
    }
    assert_eq!(sampler.seen(), 100);
    assert_eq!(sampler.flagged_count(), 0);
    let snap = sampler.snapshot();
    assert_eq!(snap.reservoir.len(), 8, "reservoir bounded at its cap");

    // Six flagged records through a cap of 4: the oldest two fall off.
    for i in 0..6u64 {
        sampler.offer(TailRecord {
            trace_id: 1000 + i,
            tenant: 3,
            total_us: 90_000,
            verdict: Verdict::SloBreach,
        });
    }
    let snap = sampler.snapshot();
    assert_eq!(snap.flagged.len(), 4);
    assert_eq!(snap.flagged_dropped, 2);
    let ids: Vec<u64> = snap.flagged.iter().map(|r| r.trace_id).collect();
    assert_eq!(ids, vec![1002, 1003, 1004, 1005], "newest-wins ring");

    // The 90 ms bucket's exemplar is flagged, and an unflagged arrival in
    // the same bucket does not displace it.
    let b = bucket_index(90_000);
    assert_eq!(sampler.exemplar(b).unwrap().verdict, Verdict::SloBreach);
    sampler.offer(TailRecord {
        trace_id: 7,
        tenant: 0,
        total_us: 90_000,
        verdict: Verdict::Ok,
    });
    let ex = sampler.exemplar(b).unwrap();
    assert_eq!(ex.verdict, Verdict::SloBreach, "flagged exemplar sticky");
}

/// End-to-end over the stats protocol frames: drive two tenant classes,
/// then assert conservation (`received == replies + shed`), a fast burn
/// > 1 under an intentionally impossible 1 µs SLO, breaching traces
/// retrievable from the tail section, and the prefix filter.
#[test]
fn live_stats_frame_conservation_burn_and_tail() {
    let qos = QosConfig {
        slo_interactive: Some(SloObjective {
            latency_us: 1, // every real retrieval breaches
            target: 0.9,
            availability: 0.999,
        }),
        slo_batch: Some(SloObjective::default()),
        ..QosConfig::default()
    };
    let mut server = CoordinatorServer::spawn_qos(
        || build_retriever(91),
        ServeMode::Concurrent(BatchPolicy::default()),
        qos,
        Tracer::off(),
    )
    .unwrap();
    let addr = server.addr;
    let ds = queries(91);
    let mut client = CoordinatorClient::connect(addr, 0).unwrap();
    for i in 0..12 {
        client.retrieve(ds.query(i % 32), &[], 10, false).unwrap();
    }
    let mut batch = CoordinatorClient::connect(addr, BATCH_TENANT_BASE).unwrap();
    for i in 0..4 {
        batch.retrieve(ds.query(i), &[], 10, false).unwrap();
    }

    // Reply counters are bumped just after the reply bytes go out, so
    // poll briefly for the final increment to land.
    let deadline = Instant::now() + Duration::from_secs(10);
    let doc = loop {
        let doc = client.stats("").unwrap();
        let srv = doc.get("server").expect("server section");
        if num(srv, "received") == 16 && num(srv, "replies") + num(srv, "shed") == 16 {
            break doc;
        }
        assert!(
            Instant::now() < deadline,
            "conservation never converged: {}",
            doc.dump()
        );
        std::thread::sleep(Duration::from_millis(20));
    };

    // Tight SLO: every interactive request breached, so the fast latency
    // burn is (12/12) / (1 - 0.9) = 10.
    let slo = doc.get("slo").and_then(|s| s.as_arr()).expect("slo array");
    let interactive = slo.iter().find(|b| num(b, "tenant") == 0).expect("tenant 0");
    let fast = interactive
        .get("latency_burn")
        .and_then(|b| b.get("fast"))
        .and_then(|f| f.as_f64())
        .unwrap();
    assert!(fast > 1.0, "fast burn should exceed 1.0, got {fast}");

    // The breaching traces are retrievable from the tail section.
    let tail = doc.get("tail").expect("tail section");
    assert!(num(tail, "flagged_total") >= 12, "{}", doc.dump());
    let flagged = tail.get("flagged").and_then(|f| f.as_arr()).unwrap();
    assert!(flagged
        .iter()
        .any(|f| f.get("verdict").and_then(|v| v.as_str()) == Some("slo_breach")));

    // Prefix filtering narrows the metrics map to the asked-for subtree.
    let filtered = client.stats("coordinator.").unwrap();
    let counters = filtered
        .get("metrics")
        .and_then(|m| m.get("counters"))
        .and_then(|c| c.as_obj())
        .unwrap();
    assert!(!counters.is_empty());
    assert!(
        counters.keys().all(|k| k.starts_with("coordinator.")),
        "{}",
        filtered.dump()
    );

    server.shutdown();
}

/// The admin gate: with `stats_admin_only`, a non-admin connection gets a
/// well-formed `{"error": ...}` body (not a dropped connection), the
/// denial is counted, and the admin connection still reads full stats.
#[test]
fn stats_admin_gate() {
    let qos = QosConfig {
        stats_admin_only: true,
        ..QosConfig::default()
    };
    let mut server = CoordinatorServer::spawn_qos(
        || build_retriever(92),
        ServeMode::Concurrent(BatchPolicy::default()),
        qos,
        Tracer::off(),
    )
    .unwrap();
    let addr = server.addr;
    let ds = queries(92);

    // conn 0 is the admin; connect it first.
    let mut admin = CoordinatorClient::connect(addr, 0).unwrap();
    admin.retrieve(ds.query(0), &[], 10, false).unwrap();
    let mut rogue = CoordinatorClient::connect(addr, 1).unwrap();
    rogue.retrieve(ds.query(1), &[], 10, false).unwrap();

    let denied = rogue.stats("").unwrap();
    assert!(
        denied.get("error").and_then(|e| e.as_str()).is_some(),
        "non-admin stats should carry an error body: {}",
        denied.dump()
    );
    assert!(server.stats().stats_denied() >= 1);
    // The rogue connection survives the denial.
    rogue.retrieve(ds.query(2), &[], 10, false).unwrap();

    let ok = admin.stats("").unwrap();
    assert!(ok.get("error").is_none());
    assert!(ok.get("server").is_some(), "{}", ok.dump());
    server.shutdown();
}

fn http_get(addr: std::net::SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// Exact-name series value from a Prometheus text body (trailing space
/// keeps `coordinator_shed` from matching `coordinator_shed_reason{...}`).
fn prom_value(body: &str, name: &str) -> i64 {
    let prefix = format!("{name} ");
    body.lines()
        .find_map(|l| l.strip_prefix(prefix.as_str()))
        .and_then(|v| v.trim().parse::<f64>().ok())
        .map(|v| v as i64)
        .unwrap_or(-1)
}

/// The hand-rolled HTTP listener serves a parseable exposition whose
/// counters satisfy the same conservation invariant mid-run scrapers
/// rely on in CI.
#[test]
fn http_scrape_exposes_conservation() {
    let mut server = CoordinatorServer::spawn_qos(
        || build_retriever(93),
        ServeMode::Concurrent(BatchPolicy::default()),
        QosConfig::default(),
        Tracer::off(),
    )
    .unwrap();
    let addr = server.addr;
    let ds = queries(93);
    let mut client = CoordinatorClient::connect(addr, 0).unwrap();
    for i in 0..8 {
        client.retrieve(ds.query(i % 32), &[], 10, false).unwrap();
    }
    let mut metrics = MetricsServer::spawn("127.0.0.1:0", server.telemetry()).unwrap();

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let body = http_get(metrics.addr);
        assert!(body.starts_with("HTTP/1.0 200"), "bad scrape reply: {body}");
        let received = prom_value(&body, "coordinator_requests_received");
        let replies = prom_value(&body, "coordinator_replies");
        let shed = prom_value(&body, "coordinator_shed");
        let backpressure = prom_value(&body, "coordinator_backpressure_frames");
        if received == 8 && replies + shed == 8 {
            assert_eq!(shed, backpressure, "sheds must equal Backpressure frames");
            assert!(body.contains("telemetry_uptime_seconds"));
            break;
        }
        assert!(
            Instant::now() < deadline,
            "scrape conservation never converged:\n{body}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    metrics.shutdown();
    server.shutdown();
}
