//! Integration tests for the open-loop load harness against a traced
//! concurrent coordinator: the run must leave a trace carrying every
//! core span kind, the per-trace critical-path stage sums must be
//! consistent with the independently measured end-to-end latency (the
//! coverage band), the server-side residency cannot exceed what the
//! client measured, and the deterministic workload must replay exactly
//! under the same seed.

use std::time::Duration;

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::batcher::BatchPolicy;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorServer, ServeMode};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;
use chameleon::loadgen::{drive, schedule, Arrival, LoadgenConfig};
use chameleon::trace::{analyze, SpanKind, Tracer};

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 3000, 16, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 48, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let corpus = Corpus::generate(3000, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, 10), corpus)
}

#[test]
fn open_loop_run_leaves_a_consistent_trace() {
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
    };
    let tracer = Tracer::new(1 << 14);
    let mut server = CoordinatorServer::spawn_traced(
        || build_retriever(31),
        ServeMode::Concurrent(policy),
        tracer.clone(),
    )
    .unwrap();
    let addr = server.addr;

    let qdata = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        64,
        16,
        33,
    );
    let queries: Vec<Vec<f32>> =
        (0..16).map(|i| qdata.query(i).to_vec()).collect();

    // Modest offered load (well under capacity) so queueing stays tame
    // and the client-side latency is dominated by server residency.
    let cfg = LoadgenConfig {
        qps: 150.0,
        n_requests: 120,
        n_unique: queries.len(),
        seed: 5,
        ..LoadgenConfig::default()
    };
    let sched = schedule(&cfg);
    let deadline = Duration::from_secs_f64(sched.span_s() + 30.0);
    let rep = drive(addr, &queries, 10, &sched, 3, deadline).unwrap();
    server.shutdown();

    assert_eq!(rep.sent, 120);
    assert!(rep.received > 0, "no replies");
    assert!(rep.interactive.is_some() && rep.batch.is_some(), "class mix missing");

    let a = analyze(&tracer.snapshot());
    assert!(a.n_traces > 0, "no traced queries");
    for kind in [
        SpanKind::QueueWait,
        SpanKind::LutBuild,
        SpanKind::NodeScan,
        SpanKind::Merge,
        SpanKind::ReplyWrite,
        SpanKind::Total,
    ] {
        assert!(
            a.kinds_present().contains(&kind),
            "missing {} spans in: {}",
            kind.name(),
            a.render()
        );
    }

    // Consistency: the per-trace critical-path stage sum must explain
    // the measured e2e residency — neither a sliver (missing spans) nor
    // wildly more than the whole (double-counted spans).
    let cov = a.coverage.as_ref().expect("no coverage");
    assert!(
        cov.p50 > 0.2 && cov.p50 < 1.3,
        "stage sums inconsistent with e2e totals: coverage p50 {:.2}\n{}",
        cov.p50,
        a.render()
    );

    // Server-side residency cannot exceed what the client measured from
    // the scheduled arrival (generous slack for clock jitter).
    let totals = a.totals.as_ref().expect("no totals");
    assert!(
        totals.p50 <= rep.latency.p50 * 1.5 + 0.02,
        "server residency p50 {:.2} ms vs client p50 {:.2} ms",
        totals.p50 * 1e3,
        rep.latency.p50 * 1e3
    );
}

#[test]
fn same_seed_replays_the_identical_workload() {
    let cfg = LoadgenConfig {
        qps: 300.0,
        n_requests: 500,
        arrival: Arrival::Bursty { period_s: 0.1, duty: 0.3 },
        zipf_alpha: 1.1,
        n_unique: 32,
        batch_fraction: 0.25,
        seed: 99,
    };
    let a = schedule(&cfg);
    let b = schedule(&cfg);
    // Bit-identical replay: arrivals, query stream AND class stream.
    assert_eq!(a, b);

    let other = schedule(&LoadgenConfig { seed: 100, ..cfg.clone() });
    assert_ne!(a.arrivals_s, other.arrivals_s);
    assert_ne!(a.query_idx, other.query_idx);
}
