//! End-to-end networked coordinator tests: GPU client -> coordinator ->
//! (in-process) memory nodes -> token conversion -> reply.

use chameleon::chamvs::dispatcher::Dispatcher;
use chameleon::chamvs::node::{MemoryNode, ScanEngine};
use chameleon::config;
use chameleon::coordinator::retriever::Retriever;
use chameleon::coordinator::server::{CoordinatorClient, CoordinatorServer};
use chameleon::data::corpus::Corpus;
use chameleon::data::synthetic::SyntheticDataset;
use chameleon::ivf::index::IvfPqIndex;
use chameleon::ivf::shard::Shard;

fn build_retriever(seed: u64) -> Retriever {
    let ds = config::dataset_by_name("SIFT").unwrap();
    let data = SyntheticDataset::generate_sized(ds, 2000, 8, seed);
    let index = IvfPqIndex::build(&data.data, data.n, data.d, ds.m, 32, seed ^ 1);
    let nodes: Vec<MemoryNode> = (0..2)
        .map(|i| MemoryNode::new(Shard::carve(&index, i, 2), ScanEngine::Native, 10))
        .collect();
    let corpus = Corpus::generate(2000, 2048, config::CHUNK_LEN, seed ^ 2);
    Retriever::new(ds, index, Dispatcher::new(nodes, 10), corpus)
}

#[test]
fn gpu_client_retrieves_tokens() {
    let mut server = CoordinatorServer::spawn_with(|| build_retriever(11)).unwrap();
    let mut client = CoordinatorClient::connect(server.addr, 0).unwrap();

    // Reference retrieval against an identical local stack.
    let mut local = build_retriever(11);
    let ds = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        8,
        11,
    );
    for qi in 0..3 {
        let q = ds.query(qi);
        let lists = local.index.probe(q, local.ds.nprobe);
        let want = local.retrieve(q).unwrap();
        let want_tokens = local.gather_next_tokens(&want.ids);

        let resp = client.retrieve(q, &lists, 10, false).unwrap();
        assert_eq!(resp.tokens.len(), 10);
        assert_eq!(resp.tokens, want_tokens, "query {qi}");
        assert_eq!(resp.dists.len(), 10);
        assert!(resp.dists.windows(2).all(|w| w[0] <= w[1]));
    }
    client.shutdown_coordinator();
    server.shutdown();
}

#[test]
fn chunk_retrieval_for_encdec() {
    let mut server = CoordinatorServer::spawn_with(|| build_retriever(13)).unwrap();
    let mut client = CoordinatorClient::connect(server.addr, 1).unwrap();
    let ds = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        8,
        13,
    );
    let resp = client.retrieve(ds.query(0), &[], 10, true).unwrap();
    // Chunks: K * CHUNK_LEN tokens even with an empty probe (empty topk
    // means zero chunks — allow both shapes).
    assert!(resp.tokens.len() % config::CHUNK_LEN == 0);
    client.shutdown_coordinator();
    server.shutdown();
}

#[test]
fn multiple_gpu_clients_sequential() {
    let mut server = CoordinatorServer::spawn_with(|| build_retriever(17)).unwrap();
    let ds = SyntheticDataset::generate_sized(
        config::dataset_by_name("SIFT").unwrap(),
        2000,
        8,
        17,
    );
    // Connections are served sequentially; each client completes its
    // round trips after the previous disconnects.
    for gpu in 0..2 {
        let mut client = CoordinatorClient::connect(server.addr, gpu).unwrap();
        let local = build_retriever(17);
        let q = ds.query(gpu as usize);
        let lists = local.index.probe(q, local.ds.nprobe);
        let resp = client.retrieve(q, &lists, 10, false).unwrap();
        assert_eq!(resp.tokens.len(), 10);
        drop(client);
    }
    server.shutdown();
}
